//! Serving metrics: latency percentiles + throughput + offline/pool
//! gauges.
//!
//! Latency storage is a fixed-size recent-window ring (a long-running
//! server must not grow a `Vec` forever): percentiles, mean and max are
//! computed over the most recent [`WINDOW`] observations, while `count`
//! and `throughput_rps` cover the server's whole lifetime.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Recent-window size for percentile math. 4096 samples ≈ minutes of
/// secure traffic; fixed memory forever.
pub const WINDOW: usize = 4096;

/// Largest batch size tracked individually by the histogram; bigger
/// batches land in the top bucket (reported as `{MAX}+`).
pub const BATCH_HIST_MAX: usize = 16;

#[derive(Debug, Default)]
struct LatencyWindow {
    /// Ring buffer of the most recent latencies (seconds).
    recent: Vec<f64>,
    /// Next write slot once the ring is full.
    next: usize,
    /// All-time observation count.
    total: u64,
}

#[derive(Debug)]
pub struct Metrics {
    window: Mutex<LatencyWindow>,
    /// Offline correlated-randomness bytes consumed by this engine's
    /// requests (dealer corrections or pooled bundles).
    offline_bytes: AtomicU64,
    /// Dynamic batches executed (secure engine: one shared round
    /// schedule each — see PERF.md §Cross-request batching).
    batches: AtomicU64,
    /// Requests served through those batches (Σ batch sizes).
    batched_requests: AtomicU64,
    /// Batch-size histogram; index = `min(size, BATCH_HIST_MAX)`.
    batch_hist: [AtomicU64; BATCH_HIST_MAX + 1],
    /// Total online protocol rounds across all batches — with the
    /// all-time request count this yields the rounds-per-request gauge,
    /// the amortization the batcher exists to drive down.
    rounds_total: AtomicU64,
    /// Failed sessions whose requests were re-enqueued for another
    /// attempt (counted once per failed session, not per request).
    sessions_retried: AtomicU64,
    /// Sessions that failed terminally — retry budget exhausted or a
    /// non-retryable error; their requests got error replies.
    sessions_failed: AtomicU64,
    started: Instant,
}

#[derive(Clone, Debug, Default)]
pub struct MetricsSummary {
    /// All-time request count.
    pub count: usize,
    /// Mean/percentiles/max over the recent window (≤ [`WINDOW`] samples).
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub max_s: f64,
    /// All-time requests per second.
    pub throughput_rps: f64,
    /// Offline correlated-randomness bytes drawn, all time (dealer
    /// corrections, or pooled bundles — a pooled session that diverges
    /// from its plan still spends its bundle, like any one-time pad).
    pub offline_bytes: u64,
    /// Ready bundles in the tuple pool (0 when serving unpooled).
    pub pool_depth: usize,
    /// Pool hit-rate in [0, 1] (1.0 when serving unpooled).
    pub pool_hit_rate: f64,
    /// Mean dynamic-batch size, all time (0.0 until a batch ran).
    pub mean_batch_size: f64,
    /// Online protocol rounds per request, all time (0.0 until a batch
    /// ran). With cross-request batching a batch of B shares ONE round
    /// schedule, so this gauge drops ~B× under load.
    pub rounds_per_request: f64,
    /// Batch-size histogram: `(size, count)` rows with non-zero counts,
    /// ascending; sizes ≥ [`BATCH_HIST_MAX`] share the top row.
    pub batch_hist: Vec<(usize, u64)>,
    /// Failed sessions re-enqueued for another attempt, all time
    /// (counted per failed session).
    pub sessions_retried: u64,
    /// Sessions that failed terminally (retry budget exhausted or a
    /// non-retryable [`crate::net::error::SessionError`]), all time.
    pub sessions_failed: u64,
    /// Successful party-link re-dials since startup (0 without a remote
    /// peer; filled by the coordinator from its link supervisor).
    pub party_reconnects: u64,
    /// Whether the party link is currently up (`true` for in-process
    /// serving, which has no link to lose).
    pub link_up: bool,
    /// Successful dealer-link re-dials since startup (0 without a
    /// remote dealer; filled from the bundle source).
    pub dealer_reconnects: u64,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            window: Mutex::new(LatencyWindow::default()),
            offline_bytes: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_requests: AtomicU64::new(0),
            batch_hist: std::array::from_fn(|_| AtomicU64::new(0)),
            rounds_total: AtomicU64::new(0),
            sessions_retried: AtomicU64::new(0),
            sessions_failed: AtomicU64::new(0),
            started: Instant::now(),
        }
    }

    /// Record one failed session whose requests were re-enqueued for
    /// another attempt.
    pub fn note_session_retry(&self) {
        self.sessions_retried.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one terminally failed session (its requests received
    /// error replies).
    pub fn note_session_failure(&self) {
        self.sessions_failed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one executed dynamic batch: its size and the online rounds
    /// its (shared) schedule cost.
    pub fn observe_batch(&self, size: usize, rounds: u64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests.fetch_add(size as u64, Ordering::Relaxed);
        self.batch_hist[size.min(BATCH_HIST_MAX)].fetch_add(1, Ordering::Relaxed);
        self.rounds_total.fetch_add(rounds, Ordering::Relaxed);
    }

    pub fn observe(&self, latency_s: f64) {
        let mut w = self.window.lock().unwrap();
        if w.recent.len() < WINDOW {
            w.recent.push(latency_s);
        } else {
            let slot = w.next;
            w.recent[slot] = latency_s;
            w.next = (slot + 1) % WINDOW;
        }
        w.total += 1;
    }

    /// Account offline bytes consumed by one finished request.
    pub fn add_offline_bytes(&self, bytes: u64) {
        self.offline_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    fn batch_gauges(&self) -> (f64, f64, Vec<(usize, u64)>) {
        let batches = self.batches.load(Ordering::Relaxed);
        let reqs = self.batched_requests.load(Ordering::Relaxed);
        let rounds = self.rounds_total.load(Ordering::Relaxed);
        let mean = if batches == 0 { 0.0 } else { reqs as f64 / batches as f64 };
        let rpr = if reqs == 0 { 0.0 } else { rounds as f64 / reqs as f64 };
        let hist: Vec<(usize, u64)> = self
            .batch_hist
            .iter()
            .enumerate()
            .filter_map(|(size, c)| {
                let c = c.load(Ordering::Relaxed);
                (c > 0).then_some((size, c))
            })
            .collect();
        (mean, rpr, hist)
    }

    pub fn summary(&self) -> MetricsSummary {
        let (mut v, total) = {
            let w = self.window.lock().unwrap();
            (w.recent.clone(), w.total)
        };
        let (mean_batch_size, rounds_per_request, batch_hist) = self.batch_gauges();
        let sessions_retried = self.sessions_retried.load(Ordering::Relaxed);
        let sessions_failed = self.sessions_failed.load(Ordering::Relaxed);
        if v.is_empty() {
            return MetricsSummary {
                pool_hit_rate: 1.0,
                offline_bytes: self.offline_bytes.load(Ordering::Relaxed),
                mean_batch_size,
                rounds_per_request,
                batch_hist,
                sessions_retried,
                sessions_failed,
                // Link gauges are the coordinator's to fill (it owns the
                // supervisor and the bundle source); in-process defaults.
                link_up: true,
                ..MetricsSummary::default()
            };
        }
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = v.len();
        let pct = |p: f64| v[((n as f64 * p) as usize).min(n - 1)];
        MetricsSummary {
            count: total as usize,
            mean_s: v.iter().sum::<f64>() / n as f64,
            p50_s: pct(0.50),
            p95_s: pct(0.95),
            max_s: *v.last().unwrap(),
            throughput_rps: total as f64 / self.started.elapsed().as_secs_f64().max(1e-9),
            offline_bytes: self.offline_bytes.load(Ordering::Relaxed),
            pool_depth: 0,
            pool_hit_rate: 1.0,
            mean_batch_size,
            rounds_per_request,
            batch_hist,
            sessions_retried,
            sessions_failed,
            party_reconnects: 0,
            link_up: true,
            dealer_reconnects: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_math() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.observe(i as f64 / 100.0);
        }
        let s = m.summary();
        assert_eq!(s.count, 100);
        assert!((s.mean_s - 0.505).abs() < 1e-9);
        assert!((s.p50_s - 0.51).abs() < 1e-9);
        assert!((s.p95_s - 0.96).abs() < 1e-9);
        assert!((s.max_s - 1.0).abs() < 1e-9);
    }

    #[test]
    fn empty_summary_is_zero() {
        let s = Metrics::new().summary();
        assert_eq!(s.count, 0);
        assert_eq!(s.mean_s, 0.0);
        assert_eq!(s.pool_hit_rate, 1.0);
    }

    #[test]
    fn window_is_bounded_and_percentiles_track_recent() {
        let m = Metrics::new();
        // 2× WINDOW observations: first half at 1.0 s, second half at
        // 10.0 s. The window must hold only the recent (10 s) samples.
        for _ in 0..WINDOW {
            m.observe(1.0);
        }
        for _ in 0..WINDOW {
            m.observe(10.0);
        }
        let s = m.summary();
        assert_eq!(s.count, 2 * WINDOW, "count is all-time");
        assert!((s.p50_s - 10.0).abs() < 1e-9, "percentiles are windowed");
        assert!((s.mean_s - 10.0).abs() < 1e-9);
        // Storage stays fixed.
        assert!(m.window.lock().unwrap().recent.len() == WINDOW);
    }

    #[test]
    fn offline_bytes_accumulate() {
        let m = Metrics::new();
        m.add_offline_bytes(100);
        m.add_offline_bytes(50);
        assert_eq!(m.summary().offline_bytes, 150);
    }

    #[test]
    fn batch_gauges_track_amortization() {
        let m = Metrics::new();
        assert_eq!(m.summary().mean_batch_size, 0.0);
        assert_eq!(m.summary().rounds_per_request, 0.0);
        // Two batches sharing one 300-round schedule each: 8 requests,
        // 600 rounds → 75 rounds/request, mean batch 4.
        m.observe_batch(6, 300);
        m.observe_batch(2, 300);
        // Oversized batches land in the top histogram bucket.
        m.observe_batch(BATCH_HIST_MAX + 9, 300);
        let s = m.summary();
        assert!((s.mean_batch_size - (6 + 2 + BATCH_HIST_MAX + 9) as f64 / 3.0).abs() < 1e-9);
        assert!(
            (s.rounds_per_request - 900.0 / (8 + BATCH_HIST_MAX as f64 + 9.0)).abs() < 1e-9
        );
        assert_eq!(
            s.batch_hist,
            vec![(2, 1), (6, 1), (BATCH_HIST_MAX, 1)],
            "hist rows ascend and clamp at the top bucket"
        );
    }
}
