//! Serving metrics: all-time latency quantiles, recent-window
//! throughput, per-phase latency attribution, and offline/pool gauges.
//!
//! Latency storage is a constant-memory log-bucketed histogram
//! ([`LogHistogram`]): quantiles (p50/p95/p99/p99.9), mean and max are
//! **all-time** (a long-running server never loses its tail), while
//! `recent_rps` tracks a trailing window so throughput reads true after
//! idle periods. Each request's wall-clock is additionally attributed
//! to phases (queue → share → bundle-wait → compute vs. transport) via
//! [`crate::obs::PhaseBreakdown`]; the accumulated per-phase totals
//! are what the `metrics` exposition reports.

use crate::obs::{LogHistogram, PhaseBreakdown, WindowedRate};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Largest batch size tracked individually by the histogram; bigger
/// batches land in the top bucket (reported as `{MAX}+`).
pub const BATCH_HIST_MAX: usize = 16;

/// Trailing window (seconds) for the recent-throughput gauge.
pub const RECENT_WINDOW_S: u64 = 10;

/// Phase names, in [`Metrics::phase_totals_s`] order. `compute` is
/// dispatch wall minus transport plus the reconstruct/decode tail, so
/// the five phases partition each request's total latency.
pub const PHASES: [&str; 5] = ["queue", "share", "bundle_wait", "compute", "transport"];

/// One engine's serving metrics (the coordinator keeps one per engine).
#[derive(Debug)]
pub struct Metrics {
    /// All-time latency histogram (constant memory, ~6% bucket error).
    latency: LogHistogram,
    /// Trailing-window completion counter for `recent_rps`.
    recent: WindowedRate,
    /// Per-phase latency histograms, indexed like [`PHASES`]: each
    /// request contributes one sample per phase, so phase totals AND
    /// phase quantiles (p50/p95/p99) come from the same storage.
    phase_hist: [LogHistogram; 5],
    /// Offline correlated-randomness bytes consumed by this engine's
    /// requests (dealer corrections or pooled bundles).
    offline_bytes: AtomicU64,
    /// Dynamic batches executed (secure engine: one shared round
    /// schedule each — see PERF.md §Cross-request batching).
    batches: AtomicU64,
    /// Requests served through those batches (Σ batch sizes).
    batched_requests: AtomicU64,
    /// Batch-size histogram; index = `min(size, BATCH_HIST_MAX)`.
    batch_hist: [AtomicU64; BATCH_HIST_MAX + 1],
    /// Total online protocol rounds across all batches — with the
    /// all-time request count this yields the rounds-per-request gauge,
    /// the amortization the batcher exists to drive down.
    rounds_total: AtomicU64,
    /// Failed sessions whose requests were re-enqueued for another
    /// attempt (counted once per failed session, not per request).
    sessions_retried: AtomicU64,
    /// Sessions that failed terminally — retry budget exhausted or a
    /// non-retryable error; their requests got error replies.
    sessions_failed: AtomicU64,
    /// Requests shed at admission (bounded submit queue full — see
    /// `ServingConfig::queue_cap`); they received an immediate typed
    /// [`crate::net::error::SessionError::Overloaded`] reply and never
    /// entered the queue.
    sessions_shed: AtomicU64,
    started: Instant,
}

/// Point-in-time summary of one engine's [`Metrics`], plus the
/// link/pool gauges the coordinator folds in (it owns the supervisor
/// and the bundle source).
#[derive(Clone, Debug, Default)]
pub struct MetricsSummary {
    /// All-time request count.
    pub count: usize,
    /// All-time mean latency (exact, from the histogram's sum/count).
    pub mean_s: f64,
    /// All-time median latency (log-bucketed, ≤ ~6% high).
    pub p50_s: f64,
    /// All-time 95th-percentile latency.
    pub p95_s: f64,
    /// All-time 99th-percentile latency.
    pub p99_s: f64,
    /// All-time 99.9th-percentile latency.
    pub p99_9_s: f64,
    /// All-time maximum latency (exact).
    pub max_s: f64,
    /// All-time requests per second.
    pub throughput_rps: f64,
    /// Requests per second over the trailing [`RECENT_WINDOW_S`]
    /// seconds — the honest load gauge after any idle period.
    pub recent_rps: f64,
    /// Accumulated per-phase seconds, indexed like [`PHASES`].
    pub phase_totals_s: [f64; 5],
    /// Per-phase median latency, indexed like [`PHASES`].
    pub phase_p50_s: [f64; 5],
    /// Per-phase 95th-percentile latency, indexed like [`PHASES`].
    pub phase_p95_s: [f64; 5],
    /// Per-phase 99th-percentile latency, indexed like [`PHASES`].
    pub phase_p99_s: [f64; 5],
    /// Offline correlated-randomness bytes drawn, all time (dealer
    /// corrections, or pooled bundles — a pooled session that diverges
    /// from its plan still spends its bundle, like any one-time pad).
    pub offline_bytes: u64,
    /// Ready bundles in the tuple pool (0 when serving unpooled).
    pub pool_depth: usize,
    /// Pool hit-rate in [0, 1] (1.0 when serving unpooled).
    pub pool_hit_rate: f64,
    /// Mean dynamic-batch size, all time (0.0 until a batch ran).
    pub mean_batch_size: f64,
    /// Online protocol rounds per request, all time (0.0 until a batch
    /// ran). With cross-request batching a batch of B shares ONE round
    /// schedule, so this gauge drops ~B× under load.
    pub rounds_per_request: f64,
    /// Batch-size histogram: `(size, count)` rows with non-zero counts,
    /// ascending; sizes ≥ [`BATCH_HIST_MAX`] share the top row.
    pub batch_hist: Vec<(usize, u64)>,
    /// Failed sessions re-enqueued for another attempt, all time
    /// (counted per failed session).
    pub sessions_retried: u64,
    /// Sessions that failed terminally (retry budget exhausted or a
    /// non-retryable [`crate::net::error::SessionError`]), all time.
    pub sessions_failed: u64,
    /// Requests shed at admission with a typed `Overloaded` reply
    /// (bounded submit queue full), all time. Shed requests never enter
    /// the queue, so they appear here and nowhere else.
    pub sessions_shed: u64,
    /// Successful party-link re-dials since startup (0 without a remote
    /// peer; filled by the coordinator from its link supervisor).
    pub party_reconnects: u64,
    /// Whether the party link is currently up (`true` for in-process
    /// serving, which has no link to lose).
    pub link_up: bool,
    /// Last measured party-link heartbeat RTT in milliseconds (0 until
    /// a PING/PONG pair completed; filled from the link supervisor).
    pub link_rtt_last_ms: f64,
    /// Exponentially weighted moving average of the party-link RTT in
    /// milliseconds (same source as `link_rtt_last_ms`).
    pub link_rtt_ewma_ms: f64,
    /// Successful dealer-link re-dials since startup (0 without a
    /// remote dealer; filled from the bundle source).
    pub dealer_reconnects: u64,
    /// PULL credit messages sent to the remote dealer, all time (0
    /// without a remote dealer; filled from the bundle source).
    pub dealer_pulls: u64,
    /// Bundles sitting in the remote pool's local prefetch queue (0
    /// without a remote dealer; filled from the bundle source).
    pub prefetch_depth: usize,
    /// Spool records superseded in place of rewriting (tombstones
    /// pending compaction; filled from the bundle source).
    pub spool_tombstones: u64,
    /// Spool compaction passes completed since startup (filled from
    /// the bundle source).
    pub spool_compactions: u64,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    /// Fresh, zeroed metrics anchored at the current instant.
    pub fn new() -> Self {
        Metrics {
            latency: LogHistogram::new(),
            recent: WindowedRate::new(),
            phase_hist: std::array::from_fn(|_| LogHistogram::new()),
            offline_bytes: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_requests: AtomicU64::new(0),
            batch_hist: std::array::from_fn(|_| AtomicU64::new(0)),
            rounds_total: AtomicU64::new(0),
            sessions_retried: AtomicU64::new(0),
            sessions_failed: AtomicU64::new(0),
            sessions_shed: AtomicU64::new(0),
            started: Instant::now(),
        }
    }

    /// Record one failed session whose requests were re-enqueued for
    /// another attempt.
    pub fn note_session_retry(&self) {
        self.sessions_retried.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one terminally failed session (its requests received
    /// error replies).
    pub fn note_session_failure(&self) {
        self.sessions_failed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one request shed at admission (bounded queue full); the
    /// caller already sent the typed `Overloaded` reply.
    pub fn note_session_shed(&self) {
        self.sessions_shed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one executed dynamic batch: its size and the online rounds
    /// its (shared) schedule cost.
    pub fn observe_batch(&self, size: usize, rounds: u64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests.fetch_add(size as u64, Ordering::Relaxed);
        self.batch_hist[size.min(BATCH_HIST_MAX)].fetch_add(1, Ordering::Relaxed);
        self.rounds_total.fetch_add(rounds, Ordering::Relaxed);
    }

    /// Record one completed request's latency.
    pub fn observe(&self, latency_s: f64) {
        self.latency.record(latency_s);
        self.recent.note();
    }

    /// Attribute one completed request's phase breakdown. The five
    /// accumulated phases partition total latency, so
    /// `Σ phase_totals_s ≈ Σ observed latencies` (within measurement
    /// slack — the invariant `tests/observability.rs` pins per request).
    pub fn observe_phases(&self, p: &PhaseBreakdown) {
        for (i, s) in
            [p.queue_s, p.share_s, p.bundle_wait_s, p.compute_s(), p.transport_s]
                .into_iter()
                .enumerate()
        {
            // `record` clamps negatives to 0; every request contributes
            // one sample per phase so the histograms stay comparable.
            self.phase_hist[i].record(s);
        }
    }

    /// Account offline bytes consumed by one finished request.
    pub fn add_offline_bytes(&self, bytes: u64) {
        self.offline_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    /// The all-time latency histogram (for `metrics` exposition).
    pub fn latency_hist(&self) -> &LogHistogram {
        &self.latency
    }

    /// All-time completed-request count.
    pub fn count(&self) -> u64 {
        self.latency.count()
    }

    /// Accumulated per-phase seconds, indexed like [`PHASES`].
    pub fn phase_totals_s(&self) -> [f64; 5] {
        std::array::from_fn(|i| self.phase_hist[i].sum_s())
    }

    /// The per-phase latency histograms, indexed like [`PHASES`] (for
    /// the `metrics` exposition's `_bucket` series).
    pub fn phase_hists(&self) -> &[LogHistogram; 5] {
        &self.phase_hist
    }

    /// The `q`-quantile of each phase's latency, indexed like [`PHASES`].
    pub fn phase_quantiles(&self, q: f64) -> [f64; 5] {
        std::array::from_fn(|i| self.phase_hist[i].quantile(q))
    }

    /// Requests per second over the trailing [`RECENT_WINDOW_S`] s.
    pub fn recent_rps(&self) -> f64 {
        self.recent.rate(RECENT_WINDOW_S)
    }

    /// `(mean batch size, rounds per request, histogram rows)`.
    pub fn batch_gauges(&self) -> (f64, f64, Vec<(usize, u64)>) {
        let batches = self.batches.load(Ordering::Relaxed);
        let reqs = self.batched_requests.load(Ordering::Relaxed);
        let rounds = self.rounds_total.load(Ordering::Relaxed);
        let mean = if batches == 0 { 0.0 } else { reqs as f64 / batches as f64 };
        let rpr = if reqs == 0 { 0.0 } else { rounds as f64 / reqs as f64 };
        let hist: Vec<(usize, u64)> = self
            .batch_hist
            .iter()
            .enumerate()
            .filter_map(|(size, c)| {
                let c = c.load(Ordering::Relaxed);
                (c > 0).then_some((size, c))
            })
            .collect();
        (mean, rpr, hist)
    }

    /// Snapshot the engine-local gauges (the coordinator fills the
    /// link/pool fields on top — see `Coordinator::secure_summary`).
    pub fn summary(&self) -> MetricsSummary {
        let (mean_batch_size, rounds_per_request, batch_hist) = self.batch_gauges();
        MetricsSummary {
            count: self.latency.count() as usize,
            mean_s: self.latency.mean_s(),
            p50_s: self.latency.quantile(0.50),
            p95_s: self.latency.quantile(0.95),
            p99_s: self.latency.quantile(0.99),
            p99_9_s: self.latency.quantile(0.999),
            max_s: self.latency.max_s(),
            throughput_rps: self.latency.count() as f64
                / self.started.elapsed().as_secs_f64().max(1e-9),
            recent_rps: self.recent_rps(),
            phase_totals_s: self.phase_totals_s(),
            phase_p50_s: self.phase_quantiles(0.50),
            phase_p95_s: self.phase_quantiles(0.95),
            phase_p99_s: self.phase_quantiles(0.99),
            offline_bytes: self.offline_bytes.load(Ordering::Relaxed),
            pool_depth: 0,
            pool_hit_rate: 1.0,
            mean_batch_size,
            rounds_per_request,
            batch_hist,
            sessions_retried: self.sessions_retried.load(Ordering::Relaxed),
            sessions_failed: self.sessions_failed.load(Ordering::Relaxed),
            sessions_shed: self.sessions_shed.load(Ordering::Relaxed),
            party_reconnects: 0,
            // Link gauges are the coordinator's to fill (it owns the
            // supervisor and the bundle source); in-process defaults.
            link_up: true,
            ..MetricsSummary::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_math() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.observe(i as f64 / 100.0);
        }
        let s = m.summary();
        assert_eq!(s.count, 100);
        // Mean and max are exact; quantiles carry ≤ ~6% bucket error.
        assert!((s.mean_s - 0.505).abs() < 1e-6);
        assert!((s.max_s - 1.0).abs() < 1e-9);
        for (got, expect) in
            [(s.p50_s, 0.50), (s.p95_s, 0.95), (s.p99_s, 0.99), (s.p99_9_s, 1.0)]
        {
            assert!(
                got >= expect * 0.999 && got <= expect * 1.07,
                "quantile {got} vs expected ~{expect}"
            );
        }
        assert!(s.p50_s <= s.p95_s && s.p95_s <= s.p99_s && s.p99_s <= s.p99_9_s);
    }

    #[test]
    fn empty_summary_is_zero() {
        let s = Metrics::new().summary();
        assert_eq!(s.count, 0);
        assert_eq!(s.mean_s, 0.0);
        assert_eq!(s.p99_9_s, 0.0);
        assert_eq!(s.pool_hit_rate, 1.0);
        assert_eq!(s.recent_rps, 0.0);
    }

    #[test]
    fn quantiles_are_all_time_in_constant_memory() {
        // The old 4096-sample ring silently turned quantiles into
        // windowed quantiles; the histogram keeps the whole history.
        let m = Metrics::new();
        for _ in 0..6000 {
            m.observe(1.0);
        }
        for _ in 0..6000 {
            m.observe(10.0);
        }
        let s = m.summary();
        assert_eq!(s.count, 12000, "count is all-time");
        // Half the all-time samples are 1.0 s — the median must see them.
        assert!(s.p50_s <= 1.0 * 1.07, "p50 {} must reflect the old half", s.p50_s);
        assert!(s.p99_s >= 10.0 * 0.99, "p99 {} must reflect the slow half", s.p99_s);
        assert!((s.max_s - 10.0).abs() < 1e-9);
    }

    #[test]
    fn recent_rps_counts_only_fresh_completions() {
        let m = Metrics::new();
        for _ in 0..50 {
            m.observe(0.01);
        }
        // All 50 completions happened "just now".
        assert!(m.recent_rps() > 0.0);
        assert!(m.summary().recent_rps > 0.0);
    }

    #[test]
    fn offline_bytes_accumulate() {
        let m = Metrics::new();
        m.add_offline_bytes(100);
        m.add_offline_bytes(50);
        assert_eq!(m.summary().offline_bytes, 150);
    }

    #[test]
    fn phase_totals_partition_latency() {
        let m = Metrics::new();
        let p = PhaseBreakdown {
            queue_s: 0.010,
            share_s: 0.002,
            bundle_wait_s: 0.001,
            dispatch_s: 0.050,
            transport_s: 0.030,
            finish_s: 0.003,
        };
        m.observe_phases(&p);
        m.observe_phases(&p);
        let totals = m.phase_totals_s();
        assert!((totals[0] - 0.020).abs() < 1e-6, "queue total");
        assert!((totals[4] - 0.060).abs() < 1e-6, "transport total");
        let sum: f64 = totals.iter().sum();
        assert!(
            (sum - 2.0 * p.total_s()).abs() < 1e-6,
            "phases must partition the total: {sum} vs {}",
            2.0 * p.total_s()
        );
        assert_eq!(PHASES.len(), totals.len());
    }

    #[test]
    fn phase_quantiles_come_from_per_request_histograms() {
        let m = Metrics::new();
        // 99 fast requests and one slow one: the queue p50 must stay
        // near the fast cluster while p99 sees the straggler.
        for _ in 0..99 {
            m.observe_phases(&PhaseBreakdown { queue_s: 0.001, ..PhaseBreakdown::default() });
        }
        m.observe_phases(&PhaseBreakdown { queue_s: 1.0, ..PhaseBreakdown::default() });
        let s = m.summary();
        assert!(s.phase_p50_s[0] <= 0.001 * 1.07, "queue p50 {}", s.phase_p50_s[0]);
        assert!(s.phase_p99_s[0] >= 0.9, "queue p99 {}", s.phase_p99_s[0]);
        // Other phases recorded 100 zero samples — quantiles stay 0.
        assert_eq!(s.phase_p95_s[1], 0.0);
        assert_eq!(m.phase_hists()[0].count(), 100);
    }

    #[test]
    fn batch_gauges_track_amortization() {
        let m = Metrics::new();
        assert_eq!(m.summary().mean_batch_size, 0.0);
        assert_eq!(m.summary().rounds_per_request, 0.0);
        // Two batches sharing one 300-round schedule each: 8 requests,
        // 600 rounds → 75 rounds/request, mean batch 4.
        m.observe_batch(6, 300);
        m.observe_batch(2, 300);
        // Oversized batches land in the top histogram bucket.
        m.observe_batch(BATCH_HIST_MAX + 9, 300);
        let s = m.summary();
        assert!((s.mean_batch_size - (6 + 2 + BATCH_HIST_MAX + 9) as f64 / 3.0).abs() < 1e-9);
        assert!(
            (s.rounds_per_request - 900.0 / (8 + BATCH_HIST_MAX as f64 + 9.0)).abs() < 1e-9
        );
        assert_eq!(
            s.batch_hist,
            vec![(2, 1), (6, 1), (BATCH_HIST_MAX, 1)],
            "hist rows ascend and clamp at the top bucket"
        );
    }
}
