//! Serving metrics: latency percentiles + throughput.

use std::sync::Mutex;
use std::time::Instant;

#[derive(Debug)]
pub struct Metrics {
    latencies_s: Mutex<Vec<f64>>,
    started: Instant,
}

#[derive(Clone, Debug, Default)]
pub struct MetricsSummary {
    pub count: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub max_s: f64,
    pub throughput_rps: f64,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Metrics { latencies_s: Mutex::new(Vec::new()), started: Instant::now() }
    }

    pub fn observe(&self, latency_s: f64) {
        self.latencies_s.lock().unwrap().push(latency_s);
    }

    pub fn summary(&self) -> MetricsSummary {
        let mut v = self.latencies_s.lock().unwrap().clone();
        if v.is_empty() {
            return MetricsSummary::default();
        }
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let count = v.len();
        let pct = |p: f64| v[((count as f64 * p) as usize).min(count - 1)];
        MetricsSummary {
            count,
            mean_s: v.iter().sum::<f64>() / count as f64,
            p50_s: pct(0.50),
            p95_s: pct(0.95),
            max_s: *v.last().unwrap(),
            throughput_rps: count as f64 / self.started.elapsed().as_secs_f64().max(1e-9),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_math() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.observe(i as f64 / 100.0);
        }
        let s = m.summary();
        assert_eq!(s.count, 100);
        assert!((s.mean_s - 0.505).abs() < 1e-9);
        assert!((s.p50_s - 0.51).abs() < 1e-9);
        assert!((s.p95_s - 0.96).abs() < 1e-9);
        assert!((s.max_s - 1.0).abs() < 1e-9);
    }

    #[test]
    fn empty_summary_is_zero() {
        let s = Metrics::new().summary();
        assert_eq!(s.count, 0);
        assert_eq!(s.mean_s, 0.0);
    }
}
