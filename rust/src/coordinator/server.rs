//! A minimal TCP front end for the coordinator (std::net — the offline
//! image has no async runtime; one thread per connection is plenty for a
//! reference server).
//!
//! Line protocol, one request per line:
//!   `secure <tok> <tok> …`   → `ok <id> <logit> <logit> … latency=<s> comm=<bytes>`
//!   `plain  <tok> <tok> …`   → same, via the PJRT artifact
//!   `stats`                  → one line of serving metrics
//!   `metrics`                → Prometheus text exposition, `# EOF`-terminated
//!   `trace <label>`          → recorded spans of one session as JSONL, `# EOF`-terminated
//!   `ledger [label]`         → per-op cost rows of one session (or the
//!                              aggregate) as JSONL, `# EOF`-terminated
//!   `quit`                   → closes the connection
//!
//! `metrics`, `trace` and `ledger` are the only multi-line replies; each
//! ends with a literal `# EOF` line so a line-oriented client knows where
//! the payload stops.

use crate::coordinator::batcher::{Coordinator, EngineKind};
use crate::nn::model::ModelInput;
use anyhow::Result;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

pub struct TcpServer {
    pub coordinator: Arc<Coordinator>,
    pub seq: usize,
    pub vocab: usize,
}

impl TcpServer {
    /// Serve forever (one thread per connection).
    pub fn serve(&self, addr: &str) -> Result<()> {
        let listener = TcpListener::bind(addr)?;
        eprintln!("secformer coordinator listening on {addr}");
        for stream in listener.incoming() {
            let stream = stream?;
            let coord = self.coordinator.clone();
            let (seq, vocab) = (self.seq, self.vocab);
            std::thread::spawn(move || {
                let _ = handle_conn(stream, &coord, seq, vocab);
            });
        }
        Ok(())
    }
}

pub fn handle_conn(
    stream: TcpStream,
    coord: &Coordinator,
    seq: usize,
    vocab: usize,
) -> Result<()> {
    let peer = stream.peer_addr()?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        let reply = handle_line(&line, coord, seq, vocab);
        match reply {
            Some(text) => writeln!(writer, "{text}")?,
            None => break,
        }
    }
    eprintln!("connection {peer} closed");
    Ok(())
}

/// Parse + dispatch one protocol line. `None` = close connection.
pub fn handle_line(line: &str, coord: &Coordinator, seq: usize, vocab: usize) -> Option<String> {
    let mut parts = line.split_whitespace();
    let cmd = parts.next().unwrap_or("");
    match cmd {
        "quit" => None,
        "" => Some(String::new()),
        "stats" => {
            let s = coord.secure_summary();
            let p = coord.metrics_plain.summary();
            let g = coord.sched_snapshot();
            // Batch-size histogram as `size:count` pairs (top bucket is
            // "{BATCH_HIST_MAX}+"), so the round amortization is
            // observable in production from one line.
            let hist = if s.batch_hist.is_empty() {
                "-".to_string()
            } else {
                s.batch_hist
                    .iter()
                    .map(|&(size, count)| {
                        if size >= crate::coordinator::metrics::BATCH_HIST_MAX {
                            format!("{size}+:{count}")
                        } else {
                            format!("{size}:{count}")
                        }
                    })
                    .collect::<Vec<_>>()
                    .join(",")
            };
            // Per-phase quantiles in PHASES order, comma-joined, so the
            // one-line summary shows where request time concentrates.
            let phase_q = |a: &[f64; 5]| {
                crate::coordinator::metrics::PHASES
                    .iter()
                    .zip(a)
                    .map(|(n, v)| format!("{n}:{v:.3}"))
                    .collect::<Vec<_>>()
                    .join(",")
            };
            Some(format!(
                "secure: n={} mean={:.3}s p95={:.3}s p99={:.3}s p99.9={:.3}s rps={:.2} \
                 recent_rps={:.2} offline_bytes={} \
                 pool_depth={} pool_hit={:.2} batch_mean={:.2} rounds_per_req={:.1} \
                 batch_hist={} phase_p50=[{}] phase_p95=[{}] phase_p99=[{}] \
                 retried={} failed={} shed={} \
                 sched_permits={} sched_running={} sched_parked={} sched_waiting={} \
                 party_reconnects={} link={} \
                 rtt_ms={:.3} rtt_ewma_ms={:.3} \
                 dealer_reconnects={} dealer_pulls={} prefetch_depth={} \
                 spool_tombstones={} spool_compactions={} \
                 | plain: n={} mean={:.4}s p95={:.4}s",
                s.count,
                s.mean_s,
                s.p95_s,
                s.p99_s,
                s.p99_9_s,
                s.throughput_rps,
                s.recent_rps,
                s.offline_bytes,
                s.pool_depth,
                s.pool_hit_rate,
                s.mean_batch_size,
                s.rounds_per_request,
                hist,
                phase_q(&s.phase_p50_s),
                phase_q(&s.phase_p95_s),
                phase_q(&s.phase_p99_s),
                s.sessions_retried,
                s.sessions_failed,
                s.sessions_shed,
                g.permits,
                g.running,
                g.parked,
                g.waiting,
                s.party_reconnects,
                if s.link_up { "up" } else { "down" },
                s.link_rtt_last_ms,
                s.link_rtt_ewma_ms,
                s.dealer_reconnects,
                s.dealer_pulls,
                s.prefetch_depth,
                s.spool_tombstones,
                s.spool_compactions,
                p.count,
                p.mean_s,
                p.p95_s
            ))
        }
        "metrics" => {
            // Multi-line: the exposition ends with "# EOF\n"; strip the
            // final newline so the connection loop's writeln restores it
            // without doubling.
            Some(coord.render_metrics().trim_end().to_string())
        }
        "trace" => match parts.next() {
            Some(label) => Some(coord.render_trace(label).trim_end().to_string()),
            None => Some("err trace needs a session label".to_string()),
        },
        // `ledger` with no label renders the process-lifetime aggregate;
        // with a label, one recent session's table.
        "ledger" => Some(coord.render_ledger(parts.next().unwrap_or("")).trim_end().to_string()),
        "secure" | "plain" => {
            let toks: Result<Vec<u32>, _> = parts.map(|t| t.parse::<u32>()).collect();
            let toks = match toks {
                Ok(t) => t,
                Err(e) => return Some(format!("err bad token: {e}")),
            };
            if toks.len() != seq {
                return Some(format!("err expected {seq} tokens, got {}", toks.len()));
            }
            if let Some(&bad) = toks.iter().find(|&&t| t as usize >= vocab) {
                return Some(format!("err token {bad} out of vocab {vocab}"));
            }
            let engine = if cmd == "secure" { EngineKind::Secure } else { EngineKind::Plaintext };
            let r = coord.infer_blocking(ModelInput::Tokens(toks), engine);
            if let Some(e) = &r.error {
                // Terminal session failure (retry budget spent or a
                // non-retryable error): the client gets a typed error
                // line instead of a hung or dropped connection.
                return Some(format!("err session failed: {e}"));
            }
            let logits = r
                .logits
                .iter()
                .map(|v| format!("{v:.6}"))
                .collect::<Vec<_>>()
                .join(" ");
            Some(format!(
                "ok {} {} latency={:.4}s comm={}",
                r.id, logits, r.latency_s, r.comm_bytes
            ))
        }
        other => Some(format!("err unknown command '{other}'")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatcherConfig;
    use crate::nn::config::{Framework, ModelConfig};
    use crate::nn::weights::random_weights;

    fn coord() -> (Coordinator, ModelConfig) {
        let cfg = ModelConfig::tiny(8, Framework::SecFormer);
        let w = random_weights(&cfg, 13);
        (
            Coordinator::start(cfg.clone(), w, None, BatcherConfig::default()).unwrap(),
            cfg,
        )
    }

    #[test]
    fn protocol_secure_request() {
        let (c, cfg) = coord();
        let line = format!(
            "secure {}",
            (0..cfg.seq).map(|i| i.to_string()).collect::<Vec<_>>().join(" ")
        );
        let reply = handle_line(&line, &c, cfg.seq, cfg.vocab).unwrap();
        assert!(reply.starts_with("ok "), "{reply}");
        assert!(reply.contains("comm="));
        c.shutdown();
    }

    #[test]
    fn protocol_validation() {
        let (c, cfg) = coord();
        assert!(handle_line("secure 1 2", &c, cfg.seq, cfg.vocab)
            .unwrap()
            .starts_with("err expected"));
        assert!(handle_line("secure 1 2 3 4 5 6 7 999", &c, cfg.seq, cfg.vocab)
            .unwrap()
            .starts_with("err token"));
        assert!(handle_line("bogus", &c, cfg.seq, cfg.vocab)
            .unwrap()
            .starts_with("err unknown"));
        assert!(handle_line("quit", &c, cfg.seq, cfg.vocab).is_none());
        let stats = handle_line("stats", &c, cfg.seq, cfg.vocab).unwrap();
        assert!(stats.contains("secure:"));
        c.shutdown();
    }

    #[test]
    fn stats_line_surfaces_pool_gauges() {
        use crate::coordinator::batcher::ServingConfig;
        let cfg = ModelConfig::tiny(8, Framework::SecFormer);
        let w = random_weights(&cfg, 19);
        let c = Coordinator::start_with(
            cfg.clone(),
            w,
            None,
            BatcherConfig::default(),
            ServingConfig::pooled(1, 2),
        )
        .unwrap();
        let line = format!(
            "secure {}",
            (0..cfg.seq).map(|i| i.to_string()).collect::<Vec<_>>().join(" ")
        );
        let reply = handle_line(&line, &c, cfg.seq, cfg.vocab).unwrap();
        assert!(reply.starts_with("ok "), "{reply}");
        let stats = handle_line("stats", &c, cfg.seq, cfg.vocab).unwrap();
        assert!(stats.contains("offline_bytes="), "{stats}");
        assert!(stats.contains("pool_depth="), "{stats}");
        assert!(stats.contains("pool_hit="), "{stats}");
        assert!(stats.contains("batch_mean="), "{stats}");
        assert!(stats.contains("rounds_per_req="), "{stats}");
        assert!(stats.contains("batch_hist=1:1"), "one single-request batch: {stats}");
        assert!(stats.contains("phase_p50=[queue:"), "{stats}");
        assert!(stats.contains("phase_p99=[queue:"), "{stats}");
        assert!(stats.contains("retried=0"), "{stats}");
        assert!(stats.contains("failed=0"), "{stats}");
        assert!(stats.contains("shed=0"), "{stats}");
        assert!(stats.contains("sched_permits=1"), "{stats}");
        assert!(stats.contains("sched_running=0"), "idle after the reply: {stats}");
        assert!(stats.contains("sched_parked=0"), "{stats}");
        assert!(stats.contains("party_reconnects=0"), "{stats}");
        assert!(stats.contains("link=up"), "{stats}");
        assert!(stats.contains("dealer_reconnects=0"), "{stats}");
        c.shutdown();
    }

    #[test]
    fn metrics_and_trace_commands() {
        let (c, cfg) = coord();
        let line = format!(
            "secure {}",
            (0..cfg.seq).map(|i| i.to_string()).collect::<Vec<_>>().join(" ")
        );
        assert!(handle_line(&line, &c, cfg.seq, cfg.vocab).unwrap().starts_with("ok "));
        let metrics = handle_line("metrics", &c, cfg.seq, cfg.vocab).unwrap();
        assert!(
            metrics.contains("secformer_requests_total{role=\"coordinator\",engine=\"secure\"} 1"),
            "{metrics}"
        );
        assert!(metrics.contains("# TYPE secformer_request_latency_seconds histogram"));
        assert!(metrics.ends_with("# EOF"), "multi-line reply must be EOF-terminated");
        assert!(
            handle_line("trace", &c, cfg.seq, cfg.vocab).unwrap().starts_with("err"),
            "trace without a label is an error"
        );
        // Any recorded session's label works; take one from the ring.
        let spans = c.tracer().recent(16);
        assert!(!spans.is_empty(), "serving one request must record spans");
        let trace =
            handle_line(&format!("trace {}", spans[0].trace), &c, cfg.seq, cfg.vocab).unwrap();
        assert!(trace.contains("\"name\":\"session\""), "{trace}");
        assert!(trace.ends_with("# EOF"));
        c.shutdown();
    }

    #[test]
    fn ledger_command_renders_op_rows() {
        let (c, cfg) = coord();
        let line = format!(
            "secure {}",
            (0..cfg.seq).map(|i| i.to_string()).collect::<Vec<_>>().join(" ")
        );
        assert!(handle_line(&line, &c, cfg.seq, cfg.vocab).unwrap().starts_with("ok "));
        // Bare `ledger` renders the aggregate table.
        let agg = handle_line("ledger", &c, cfg.seq, cfg.vocab).unwrap();
        assert!(agg.contains("\"session\":\"*\""), "{agg}");
        assert!(agg.contains("\"op\":\"attn"), "attention rows must be attributed: {agg}");
        assert!(agg.ends_with("# EOF"));
        // With a label, the session table (labels are shared with traces).
        let spans = c.tracer().recent(16);
        let label = spans[0].trace.clone();
        let one = handle_line(&format!("ledger {label}"), &c, cfg.seq, cfg.vocab).unwrap();
        assert!(one.contains(&format!("\"session\":\"{label}\"")), "{one}");
        assert!(one.ends_with("# EOF"));
        // An unknown label yields an empty (but well-formed) reply.
        assert_eq!(handle_line("ledger nope", &c, cfg.seq, cfg.vocab).unwrap(), "# EOF");
        c.shutdown();
    }

    #[test]
    fn metrics_expose_op_and_cost_model_families() {
        let (c, cfg) = coord();
        let line = format!(
            "secure {}",
            (0..cfg.seq).map(|i| i.to_string()).collect::<Vec<_>>().join(" ")
        );
        assert!(handle_line(&line, &c, cfg.seq, cfg.vocab).unwrap().starts_with("ok "));
        let metrics = handle_line("metrics", &c, cfg.seq, cfg.vocab).unwrap();
        assert!(metrics.contains("# TYPE secformer_op_rounds_total counter"), "{metrics}");
        assert!(metrics.contains("secformer_op_bytes_total{role=\"coordinator\",op=\""));
        assert!(metrics.contains("# TYPE secformer_phase_latency_seconds histogram"));
        assert!(metrics.contains("secformer_ledger_sessions_total{role=\"coordinator\"} 1"));
        // The cost-model gauges must reconcile to zero on a healthy build.
        for lineref in metrics.lines() {
            if lineref.starts_with("secformer_cost_model_rounds_delta{") {
                assert!(lineref.ends_with(" 0"), "round regression surfaced: {lineref}");
            }
        }
        assert!(
            metrics.contains("secformer_cost_model_rounds_delta{role=\"coordinator\",op=\"softmax\"} 0"),
            "{metrics}"
        );
        c.shutdown();
    }

    #[test]
    fn tcp_end_to_end() {
        let (c, cfg) = coord();
        let coord = Arc::new(c);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let c2 = coord.clone();
        let (seq, vocab) = (cfg.seq, cfg.vocab);
        std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let _ = handle_conn(stream, &c2, seq, vocab);
        });
        let mut client = TcpStream::connect(addr).unwrap();
        let line = format!(
            "secure {}\n",
            (0..cfg.seq).map(|i| i.to_string()).collect::<Vec<_>>().join(" ")
        );
        client.write_all(line.as_bytes()).unwrap();
        let mut reader = BufReader::new(client.try_clone().unwrap());
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        assert!(reply.starts_with("ok "), "{reply}");
        client.write_all(b"quit\n").unwrap();
    }
}
