//! Differential battery for the swappable compute backends.
//!
//! Ring arithmetic is exact (mod 2^64) and wrapping addition is
//! commutative/associative, so every `Kernel` implementation must be
//! **bit-identical** — a single divergent bit would silently corrupt
//! every secret share downstream. These tests hammer that contract:
//!
//! * ≥ 1000 randomized shapes, scalar vs SIMD vs a naive reference,
//!   including lane-remainder edges (k % 4 ≠ 0, n below the lane/tile
//!   width, m = 1) and empty dims;
//! * the parallel/serial sharding boundary (forced sharding at chunk-edge
//!   row counts, swept thread caps);
//! * the elementwise ring ops at remainder-heavy lengths;
//! * end-to-end logit bit-identity across `--kernel scalar|simd` under a
//!   pooled in-process topology and a remote-party (localhost TCP)
//!   topology.

use secformer::core::kernel::{
    matmul_ring, matmul_ring_with, set_kernel, Kernel, KernelChoice, KernelConfig, SCALAR, SIMD,
};
use secformer::core::rng::Xoshiro;
use std::sync::Mutex;

/// Serializes the tests that flip the process-global backend selection,
/// so each end-to-end run is attributable to one backend. (Even without
/// it the assertions would hold — backends are bit-identical — but the
/// test names would lie about what ran.)
static KERNEL_FLIP: Mutex<()> = Mutex::new(());

const SERIAL: KernelConfig = KernelConfig { max_threads: 1, par_threshold_ops: usize::MAX };

fn random_operands(m: usize, k: usize, n: usize, rng: &mut Xoshiro) -> (Vec<u64>, Vec<u64>) {
    let a: Vec<u64> = (0..m * k).map(|_| rng.next_u64()).collect();
    let b: Vec<u64> = (0..k * n).map(|_| rng.next_u64()).collect();
    (a, b)
}

/// Naive i-j-k triple loop — the definitional reference.
fn matmul_naive(a: &[u64], b: &[u64], m: usize, k: usize, n: usize) -> Vec<u64> {
    let mut c = vec![0u64; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0u64;
            for p in 0..k {
                acc = acc.wrapping_add(a[i * k + p].wrapping_mul(b[p * n + j]));
            }
            c[i * n + j] = acc;
        }
    }
    c
}

fn assert_backends_identical(
    a: &[u64],
    b: &[u64],
    m: usize,
    k: usize,
    n: usize,
    check_naive: bool,
    what: &str,
) {
    let mut c_scalar = vec![0u64; m * n];
    matmul_ring_with(&SCALAR, SERIAL, a, b, &mut c_scalar, m, k, n);
    let mut c_simd = vec![0u64; m * n];
    matmul_ring_with(&SIMD, SERIAL, a, b, &mut c_simd, m, k, n);
    assert_eq!(c_scalar, c_simd, "{what}: scalar vs simd at {m}x{k}x{n}");
    if check_naive {
        assert_eq!(c_scalar, matmul_naive(a, b, m, k, n), "{what}: vs naive at {m}x{k}x{n}");
    }
}

#[test]
fn differential_battery_randomized_shapes() {
    let mut rng = Xoshiro::seed_from(0xD1FF);
    let mut trials = 0usize;

    // Directed edges first: empty dims, m = 1, n below the SIMD column
    // tile (JT = 8) and the vector lane width (4), k around the 4-wide
    // unroll and the KB = 128 k-block boundary.
    let edges: [(usize, usize, usize); 22] = [
        (0, 5, 7),
        (3, 0, 4),
        (2, 6, 0),
        (0, 0, 0),
        (1, 1, 1),
        (1, 7, 3),
        (1, 13, 16),
        (5, 4, 1),
        (5, 5, 2),
        (4, 6, 3),
        (3, 3, 4),
        (2, 9, 5),
        (2, 10, 7),
        (3, 11, 8),
        (3, 12, 9),
        (2, 127, 11),
        (2, 128, 11),
        (2, 129, 11),
        (2, 131, 17),
        (1, 255, 9),
        (2, 257, 8),
        (7, 130, 23),
    ];
    for &(m, k, n) in &edges {
        let (a, b) = random_operands(m, k, n, &mut rng);
        assert_backends_identical(&a, &b, m, k, n, true, "edge");
        trials += 1;
    }

    // Randomized sweep. Small dims dominate (they hit every remainder
    // path: k % 4, n % 8, n % 4); every 16th trial grows k past the
    // 128-wide k-block and n past several column tiles.
    for t in 0..1024usize {
        let (m, k, n) = if t % 16 == 0 {
            (
                1 + (rng.next_u64() % 24) as usize,
                1 + (rng.next_u64() % 300) as usize,
                1 + (rng.next_u64() % 70) as usize,
            )
        } else {
            (
                (rng.next_u64() % 9) as usize,
                (rng.next_u64() % 33) as usize,
                (rng.next_u64() % 19) as usize,
            )
        };
        let (a, b) = random_operands(m, k, n, &mut rng);
        // Naive reference on a subset — it's O(mkn) with no blocking, and
        // the scalar kernel is already pinned against it on every edge.
        assert_backends_identical(&a, &b, m, k, n, t % 8 == 0, "random");
        trials += 1;
    }
    assert!(trials >= 1000, "battery must cover >= 1000 shapes, ran {trials}");
}

#[test]
fn differential_all_max_operands_wrap_identically() {
    // All-u64::MAX operands exercise maximal wrapping on every product
    // and every accumulation step. Closed form: MAX·MAX ≡ 1 (mod 2^64),
    // so each output element is exactly k mod 2^64.
    for (m, k, n) in [(3usize, 7usize, 5usize), (2, 130, 9), (1, 4, 1), (4, 64, 12)] {
        let a = vec![u64::MAX; m * k];
        let b = vec![u64::MAX; k * n];
        for kern in [&SCALAR as &dyn Kernel, &SIMD] {
            let mut c = vec![0u64; m * n];
            matmul_ring_with(kern, SERIAL, &a, &b, &mut c, m, k, n);
            assert!(
                c.iter().all(|&v| v == k as u64),
                "{} at {m}x{k}x{n}: expected all {k}",
                kern.name()
            );
        }
    }
}

#[test]
fn differential_parallel_sharding_boundary() {
    // Forced sharding (threshold 1) must be bit-identical to the serial
    // path for both backends at row counts around chunk boundaries —
    // including m = 1 (fewer rows than workers) and m = 127 (uneven last
    // chunk) — for several thread caps.
    let (k, n) = (96usize, 40usize);
    let mut rng = Xoshiro::seed_from(0x5AAD);
    for m in [1usize, 2, 3, 7, 8, 9, 127, 128] {
        let (a, b) = random_operands(m, k, n, &mut rng);
        for kern in [&SCALAR as &dyn Kernel, &SIMD] {
            let mut serial = vec![0u64; m * n];
            matmul_ring_with(kern, SERIAL, &a, &b, &mut serial, m, k, n);
            for threads in [2usize, 3, 8] {
                let cfg = KernelConfig { max_threads: threads, par_threshold_ops: 1 };
                let mut par = vec![0u64; m * n];
                matmul_ring_with(kern, cfg, &a, &b, &mut par, m, k, n);
                assert_eq!(par, serial, "{} m={m} threads={threads}", kern.name());
            }
        }
    }
    // The default entry point (global backend + config) on an
    // above-threshold shape agrees with both explicit serial backends.
    let (m, k, n) = (160usize, 80, 96); // > 2^20 MACs
    let (a, b) = random_operands(m, k, n, &mut rng);
    let mut via_global = vec![0u64; m * n];
    matmul_ring(&a, &b, &mut via_global, m, k, n);
    let mut serial = vec![0u64; m * n];
    matmul_ring_with(&SCALAR, SERIAL, &a, &b, &mut serial, m, k, n);
    assert_eq!(via_global, serial, "global dispatch vs explicit serial scalar");
}

#[test]
fn differential_elementwise_ops() {
    let mut rng = Xoshiro::seed_from(0xE7E7);
    // Lengths straddling the lane width (4) and tile remainders.
    for len in [0usize, 1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 17, 63, 64, 67] {
        let x: Vec<u64> = (0..len).map(|_| rng.next_u64()).collect();
        let y: Vec<u64> = (0..len).map(|_| rng.next_u64()).collect();
        let c = rng.next_u64();
        let (mut s, mut v) = (vec![0u64; len], vec![0u64; len]);
        SCALAR.add(&x, &y, &mut s);
        SIMD.add(&x, &y, &mut v);
        assert_eq!(s, v, "add len={len}");
        SCALAR.sub(&x, &y, &mut s);
        SIMD.sub(&x, &y, &mut v);
        assert_eq!(s, v, "sub len={len}");
        SCALAR.scale(&x, c, &mut s);
        SIMD.scale(&x, c, &mut v);
        assert_eq!(s, v, "scale len={len}");
        let (mut accs, mut accv) = (x.clone(), x.clone());
        SCALAR.add_assign(&mut accs, &y);
        SIMD.add_assign(&mut accv, &y);
        assert_eq!(accs, accv, "add_assign len={len}");
    }
    // Rowwise broadcasts at remainder-heavy column counts.
    for (rows, cols) in [(1usize, 1usize), (2, 3), (3, 4), (4, 7), (5, 9), (2, 16), (3, 17)] {
        let x: Vec<u64> = (0..rows * cols).map(|_| rng.next_u64()).collect();
        let row: Vec<u64> = (0..rows).map(|_| rng.next_u64()).collect();
        let (mut s, mut v) = (vec![0u64; rows * cols], vec![0u64; rows * cols]);
        SCALAR.mul_rowwise(&x, &row, &mut s, cols);
        SIMD.mul_rowwise(&x, &row, &mut v, cols);
        assert_eq!(s, v, "mul_rowwise {rows}x{cols}");
        SCALAR.sub_rowwise(&x, &row, &mut s, cols);
        SIMD.sub_rowwise(&x, &row, &mut v, cols);
        assert_eq!(s, v, "sub_rowwise {rows}x{cols}");
    }
}

// =====================================================================
// End-to-end logit bit-identity across backends
// =====================================================================

mod e2e {
    use super::*;
    use secformer::engine::{OfflineMode, SecureModel};
    use secformer::nn::config::{Framework, ModelConfig};
    use secformer::nn::model::ModelInput;
    use secformer::nn::weights::{random_weights, share_weights, WeightMap};
    use secformer::offline::pool::PoolConfig;
    use secformer::offline::source::{BundleSource, PoolSet};
    use secformer::party::runtime::{spawn_party_host, PartyHostConfig};
    use std::sync::Arc;

    fn tiny() -> ModelConfig {
        ModelConfig::tiny(8, Framework::SecFormer)
    }

    fn hidden_input(cfg: &ModelConfig, seed: u64) -> ModelInput {
        let mut rng = Xoshiro::seed_from(seed);
        ModelInput::Hidden((0..cfg.seq * cfg.hidden).map(|_| rng.normal() * 0.5).collect())
    }

    fn shares1(w: &WeightMap) -> secformer::nn::weights::ShareMap {
        // The engine's fixed sharing seed: equal weights ⇒ equal shares.
        let (_, s1) = share_weights(w, &mut Xoshiro::seed_from(0x5EC0));
        s1
    }

    fn pool_set(cfg: &ModelConfig, prefix: &str) -> Arc<PoolSet> {
        PoolSet::start(
            cfg,
            prefix,
            PoolConfig { target_depth: 4, producers: 1, ..PoolConfig::default() },
            true,
        )
    }

    fn assert_bit_identical(a: &[f64], b: &[f64], what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: logit count");
        for i in 0..a.len() {
            assert!(a[i].is_finite(), "{what}: logit {i} not finite");
            assert_eq!(
                a[i].to_bits(),
                b[i].to_bits(),
                "{what}: logit {i} differs: scalar={} simd={}",
                a[i],
                b[i]
            );
        }
    }

    /// Run `f` once per backend (scalar, then SIMD), restoring
    /// auto-detection afterwards, and return both results.
    fn with_each_backend<T>(mut f: impl FnMut() -> T) -> (T, T) {
        let _guard = KERNEL_FLIP.lock().unwrap_or_else(|p| p.into_inner());
        set_kernel(KernelChoice::Scalar);
        let scalar = f();
        set_kernel(KernelChoice::Simd);
        let simd = f();
        set_kernel(KernelChoice::Auto);
        (scalar, simd)
    }

    #[test]
    fn pooled_logits_bit_identical_across_kernels() {
        // Same pooled in-process engine topology, same session labels,
        // one run per backend: the full secure forward pass — triple
        // generation, Beaver reconstruction, every protocol — must
        // produce bit-identical logits.
        let cfg = tiny();
        let w = random_weights(&cfg, 91);
        let input = hidden_input(&cfg, 17);
        let mut run = |prefix: &str| {
            let mut model = SecureModel::new_pooled(cfg.clone(), &w, pool_set(&cfg, prefix));
            model.set_session_label("kern-pooled");
            model.infer(&input).logits
        };
        // Distinct pool prefixes per run (one-time-pad hygiene): the
        // correlated randomness DIFFERS between the two runs, yet the
        // reconstructed logits may not — bit-identity must hold
        // independently of the randomness, not just transcript-for-
        // transcript.
        let mut round = 0u32;
        let (scalar, simd) = with_each_backend(|| {
            round += 1;
            run(&format!("kern-pool-{round}"))
        });
        assert_bit_identical(&scalar, &simd, "pooled");
    }

    #[test]
    fn remote_party_logits_bit_identical_across_kernels() {
        // Remote-party topology: S1 lives in a `spawn_party_host`
        // process-twin behind a real localhost TCP socket (pooled source
        // on both sides, session-aligned on label/prefix). One full
        // remote inference per backend; logits must match bit-for-bit.
        let cfg = tiny();
        let w = random_weights(&cfg, 92);
        let input = hidden_input(&cfg, 23);
        let mut run = |prefix: &str| {
            let addr = spawn_party_host(
                cfg.clone(),
                Arc::new(shares1(&w)),
                Some(pool_set(&cfg, prefix) as Arc<dyn BundleSource>),
                PartyHostConfig::default(),
            )
            .expect("spawn party host");
            let mut model = SecureModel::new_pooled(cfg.clone(), &w, pool_set(&cfg, prefix));
            model.set_session_label("kern-remote");
            model
                .connect_remote_peer(&addr.to_string(), None)
                .expect("connect to party host");
            model.infer(&input).logits
        };
        let mut round = 0u32;
        let (scalar, simd) = with_each_backend(|| {
            round += 1;
            run(&format!("kern-remote-{round}"))
        });
        assert_bit_identical(&scalar, &simd, "remote-party");
    }

    #[test]
    fn seeded_logits_bit_identical_across_kernels() {
        // Cheapest end-to-end cross-check: the in-process seeded engine.
        let cfg = tiny();
        let w = random_weights(&cfg, 93);
        let input = hidden_input(&cfg, 31);
        let (scalar, simd) = with_each_backend(|| {
            let mut model = SecureModel::new(cfg.clone(), &w, OfflineMode::Seeded);
            model.set_session_label("kern-seeded");
            model.infer(&input).logits
        });
        assert_bit_identical(&scalar, &simd, "seeded");
    }
}
