//! Integration tests over the public API: full secure inferences across
//! all four frameworks, the serving coordinator, artifact execution, and
//! cross-layer consistency (cost model ↔ measured engine stats).

use secformer::coordinator::{BatcherConfig, Coordinator, EngineKind};
use secformer::core::rng::Xoshiro;
use secformer::engine::{OfflineMode, SecureModel};
use secformer::net::stats::OpCategory;
use secformer::nn::config::{Framework, ModelConfig};
use secformer::nn::model::{ref_forward, ModelInput};
use secformer::nn::weights::{load_swts, random_weights, save_swts};

fn hidden_input(cfg: &ModelConfig, seed: u64) -> ModelInput {
    let mut rng = Xoshiro::seed_from(seed);
    ModelInput::Hidden((0..cfg.seq * cfg.hidden).map(|_| rng.normal() * 0.5).collect())
}

#[test]
fn all_frameworks_run_and_secformer_matches_reference_best() {
    // Every framework must complete a secure inference; the approximation
    // frameworks whose reference semantics we mirror must agree with it.
    for fw in Framework::ALL {
        let cfg = ModelConfig::tiny(8, fw);
        let w = random_weights(&cfg, 21);
        let input = hidden_input(&cfg, 22);
        let mut m = SecureModel::new(cfg.clone(), &w, OfflineMode::Seeded);
        let got = m.infer(&input);
        assert_eq!(got.logits.len(), cfg.num_labels, "{fw:?}");
        assert!(got.logits.iter().all(|v| v.is_finite()), "{fw:?}");
        if matches!(fw, Framework::SecFormer | Framework::MpcFormer) {
            let expect = ref_forward(&cfg, &w, &input);
            for i in 0..cfg.num_labels {
                assert!(
                    (got.logits[i] - expect[i]).abs() < 0.2,
                    "{fw:?} logit {i}: {} vs {}",
                    got.logits[i],
                    expect[i]
                );
            }
        }
    }
}

#[test]
fn secformer_cheaper_than_exact_frameworks_in_engine_stats() {
    // Table 3's shape, at tiny scale, from the real engine counters:
    // softmax comm: SecFormer ≪ CrypTen/PUMA; gelu comm: SecFormer < PUMA.
    let mut by_fw = std::collections::HashMap::new();
    for fw in Framework::ALL {
        let cfg = ModelConfig::tiny(16, fw);
        let w = random_weights(&cfg, 31);
        let input = hidden_input(&cfg, 32);
        let mut m = SecureModel::new(cfg.clone(), &w, OfflineMode::Seeded);
        let r = m.infer(&input);
        by_fw.insert(fw, r.stats);
    }
    let sm = |f: Framework| by_fw[&f].bytes[OpCategory::Softmax as usize];
    let ge = |f: Framework| by_fw[&f].bytes[OpCategory::Gelu as usize];
    let ln = |f: Framework| by_fw[&f].bytes[OpCategory::LayerNorm as usize];
    assert!(sm(Framework::SecFormer) * 5 < sm(Framework::Puma));
    assert!(sm(Framework::SecFormer) * 5 < sm(Framework::Crypten));
    assert!(ge(Framework::SecFormer) < ge(Framework::Puma));
    assert!(ge(Framework::MpcFormer) * 10 < ge(Framework::SecFormer));
    assert!(ln(Framework::SecFormer) < ln(Framework::Crypten));
    // Totals: at tiny scale linear ops ("Others") weigh more than at BERT
    // scale, so assert the ordering only; the 3.57× factor is checked at
    // bench scale (EXPERIMENTS.md Table 3).
    // (CrypTen's total is omitted here: its cheap-but-wrong Taylor GeLU
    // makes it comm-light at tiny seq; the crossover to the paper's
    // ordering happens as seq² softmax terms grow — see Table 3 bench.)
    // At tiny shapes Π_GeLU dominates SecFormer's bill (the paper's 41%-
    // of-time observation, amplified); the SecFormer≈1.05×MPCFormer total
    // emerges at BERT shapes where linear layers weigh in (Table 3 bench).
    let tot = |f: Framework| by_fw[&f].total_bytes();
    assert!(tot(Framework::SecFormer) < tot(Framework::Puma));
    assert!(tot(Framework::SecFormer) < tot(Framework::MpcFormer) * 8);
}

#[test]
fn engine_gelu_comm_matches_cost_model_exactly() {
    // The analytic model must agree with the engine's live counters.
    let cfg = ModelConfig::tiny(8, Framework::SecFormer);
    let w = random_weights(&cfg, 41);
    let input = hidden_input(&cfg, 42);
    let mut m = SecureModel::new(cfg.clone(), &w, OfflineMode::Seeded);
    let r = m.infer(&input);
    let gelu_elems = (cfg.layers * cfg.seq * cfg.intermediate) as f64;
    let predicted_bits = secformer::proto::cost::gelu_secformer().bits * gelu_elems;
    let measured_bits = (r.stats.bytes[OpCategory::Gelu as usize] * 8 * 2) as f64;
    let rel = (measured_bits - predicted_bits).abs() / predicted_bits;
    assert!(rel < 0.02, "measured {measured_bits} vs predicted {predicted_bits}");
}

#[test]
fn coordinator_mixed_engines_and_metrics() {
    let cfg = ModelConfig::tiny(8, Framework::SecFormer);
    let w = random_weights(&cfg, 51);
    let coord = Coordinator::start(cfg.clone(), w, None, BatcherConfig::default()).unwrap();
    let (tx, rx) = std::sync::mpsc::channel();
    for i in 0..4u32 {
        let toks: Vec<u32> = (0..cfg.seq as u32).map(|j| (i + j) % cfg.vocab as u32).collect();
        coord.submit(ModelInput::Tokens(toks), EngineKind::Secure, tx.clone());
    }
    let mut ids = std::collections::BTreeSet::new();
    for _ in 0..4 {
        let r = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
        assert!(r.comm_bytes > 0);
        ids.insert(r.id);
    }
    assert_eq!(ids.len(), 4);
    let s = coord.metrics_secure.summary();
    assert_eq!(s.count, 4);
    assert!(s.p95_s >= s.p50_s);
    coord.shutdown();
}

#[test]
fn swts_roundtrip_through_engine() {
    // save → load → secure inference gives the same logits as the original.
    let cfg = ModelConfig::tiny(8, Framework::SecFormer);
    let w = random_weights(&cfg, 61);
    let path = "/tmp/secformer_integration.swts";
    save_swts(path, &w).unwrap();
    let w2 = load_swts(path).unwrap();
    let input = hidden_input(&cfg, 62);
    let a = SecureModel::new(cfg.clone(), &w, OfflineMode::Seeded).infer(&input);
    let b = SecureModel::new(cfg.clone(), &w2, OfflineMode::Seeded).infer(&input);
    for i in 0..cfg.num_labels {
        // f32 quantization of the .swts format only.
        assert!((a.logits[i] - b.logits[i]).abs() < 0.01);
    }
}

#[test]
fn failure_injection_bad_weights_file() {
    std::fs::write("/tmp/secformer_bad.swts", b"not a weights file").unwrap();
    assert!(load_swts("/tmp/secformer_bad.swts").is_err());
    assert!(load_swts("/tmp/definitely_missing_12345.swts").is_err());
}

#[test]
#[should_panic(expected = "hidden input must be seq×hidden")]
fn failure_injection_wrong_input_shape() {
    let cfg = ModelConfig::tiny(8, Framework::SecFormer);
    let w = random_weights(&cfg, 71);
    let mut m = SecureModel::new(cfg, &w, OfflineMode::Seeded);
    // 3 values instead of seq×hidden.
    let _ = m.infer(&ModelInput::Hidden(vec![0.0, 1.0, 2.0]));
}

#[test]
fn deterministic_comm_accounting() {
    // Communication is a pure function of the model shape — two runs (and
    // both offline modes) must count identical online volumes.
    let cfg = ModelConfig::tiny(8, Framework::SecFormer);
    let w = random_weights(&cfg, 81);
    let input = hidden_input(&cfg, 82);
    let a = SecureModel::new(cfg.clone(), &w, OfflineMode::Seeded).infer(&input);
    let b = SecureModel::new(cfg.clone(), &w, OfflineMode::Seeded).infer(&input);
    let c = SecureModel::new(cfg.clone(), &w, OfflineMode::Dealer).infer(&input);
    assert_eq!(a.stats.total_bytes(), b.stats.total_bytes());
    assert_eq!(a.stats.total_rounds(), b.stats.total_rounds());
    assert_eq!(a.stats.total_bytes(), c.stats.total_bytes());
    assert_eq!(a.stats.total_rounds(), c.stats.total_rounds());
}

#[test]
fn causal_extension_matches_reference_and_masks_future() {
    // §6 future-work extension: decoder-style causal attention.
    let mut cfg = ModelConfig::tiny(8, Framework::SecFormer);
    cfg.causal = true;
    let w = random_weights(&cfg, 91);
    let input = hidden_input(&cfg, 92);
    let mut m = SecureModel::new(cfg.clone(), &w, OfflineMode::Seeded);
    let got = m.infer(&input);
    let expect = ref_forward(&cfg, &w, &input);
    for i in 0..cfg.num_labels {
        assert!(
            (got.logits[i] - expect[i]).abs() < 0.2,
            "causal logit {i}: {} vs {}",
            got.logits[i],
            expect[i]
        );
    }
    // Masking invariance: the [CLS] (position 0) representation — and the
    // classifier logits read from it — must be independent of every later
    // token when attention is causal (plaintext check).
    if let ModelInput::Hidden(h) = &input {
        let mut h2 = h.clone();
        for v in h2[cfg.hidden..].iter_mut() {
            *v += 0.37; // perturb everything except position 0
        }
        let a = ref_forward(&cfg, &w, &ModelInput::Hidden(h.clone()));
        let b = ref_forward(&cfg, &w, &ModelInput::Hidden(h2));
        for i in 0..cfg.num_labels {
            assert!((a[i] - b[i]).abs() < 1e-9, "future tokens leaked into CLS");
        }
    }
}
