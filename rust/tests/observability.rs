//! Integration tests for the unified telemetry subsystem, pinning the
//! PR's acceptance criteria:
//!
//! 1. all three roles answer `metrics` with a well-formed Prometheus
//!    text exposition under ONE name schema (`secformer_*`, every
//!    sample labelled with its role);
//! 2. the phase decomposition is honest: per-phase latency totals sum
//!    to total measured latency within 5%, under both the pooled
//!    in-process topology and a real remote party link;
//! 3. spans of one inference join across coordinator and party by the
//!    session label alone — the trace id IS the label already on the
//!    wire;
//! 4. tracing is observation-only: logits, rounds and bytes are
//!    bit-identical with the tracer on or off, and the overhead stays
//!    bounded;
//! 5. metrics stay consistent under concurrent load.

use secformer::coordinator::{BatcherConfig, Coordinator, EngineKind, ServingConfig};
use secformer::coordinator::metrics::PHASES;
use secformer::core::rng::Xoshiro;
use secformer::nn::config::{Framework, ModelConfig};
use secformer::nn::model::ModelInput;
use secformer::nn::weights::{random_weights, share_weights, ShareMap, WeightMap};
use secformer::offline::planner::PlanInput;
use secformer::offline::pool::PoolConfig;
use secformer::offline::remote::{
    fetch_dealer_metrics, fetch_dealer_trace, spawn_dealer, spawn_dealer_with, DealerConfig,
    RemotePool, RemotePoolConfig,
};
use secformer::offline::source::{BundleSource, PoolSet};
use secformer::party::runtime::{
    fetch_party_metrics, fetch_party_trace, spawn_party_host, LinkOptions, PartyHostConfig,
    RemoteParty,
};
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

fn tiny() -> ModelConfig {
    ModelConfig::tiny(8, Framework::SecFormer)
}

fn tokens(cfg: &ModelConfig, shift: u32) -> Vec<u32> {
    (0..cfg.seq as u32).map(|i| (i + shift) % cfg.vocab as u32).collect()
}

/// The engine's fixed sharing seed: equal weights ⇒ equal share maps ⇒
/// a matching HELLO fingerprint between coordinator and party host.
fn shares1(w: &WeightMap) -> ShareMap {
    let (_, s1) = share_weights(w, &mut Xoshiro::seed_from(0x5EC0));
    s1
}

/// Structural validation of one Prometheus text exposition: every
/// sample line is `name{labels} value` with a `secformer_` name, the
/// expected `role` label and a parseable finite value; every histogram
/// bucket series is monotone with its `+Inf` bucket equal to `_count`;
/// the body ends with the `# EOF` terminator.
fn assert_well_formed_exposition(text: &str, role: &str) {
    assert!(text.ends_with("# EOF\n") || text.ends_with("# EOF"), "missing EOF: {text:?}");
    let mut samples = 0usize;
    let mut bucket_prev: Option<f64> = None;
    // `+Inf` bucket and `_count` per histogram series (keyed by the
    // series' label set, so multi-row families compare row-to-row).
    let mut bucket_inf: HashMap<String, f64> = HashMap::new();
    let mut hist_count: HashMap<String, f64> = HashMap::new();
    for line in text.lines() {
        if line.starts_with('#') || line.is_empty() {
            continue;
        }
        let (series, value) = line.rsplit_once(' ').unwrap_or_else(|| {
            panic!("sample line without value: {line:?}");
        });
        assert!(series.starts_with("secformer_"), "unprefixed sample: {line:?}");
        assert!(
            series.contains(&format!("role=\"{role}\"")),
            "sample without role label: {line:?}"
        );
        let v: f64 = value.parse().unwrap_or_else(|e| {
            panic!("unparseable value in {line:?}: {e}");
        });
        assert!(v.is_finite(), "non-finite sample: {line:?}");
        samples += 1;
        // Cumulative-bucket monotonicity within each histogram row; a
        // `+Inf` bucket closes the row and must equal that row's
        // `_count`.
        if series.contains("_bucket{") {
            if let Some(prev) = bucket_prev {
                assert!(v >= prev, "non-monotone bucket: {line:?}");
            }
            bucket_prev = Some(v);
            if series.contains("le=\"+Inf\"") {
                bucket_inf.insert(
                    series.replace(",le=\"+Inf\"", "").replace("le=\"+Inf\"", ""),
                    v,
                );
                bucket_prev = None; // the next row's series restarts
            }
        } else if series.contains("_count{") {
            hist_count.insert(series.replace("_count{", "_bucket{"), v);
        }
    }
    assert!(samples > 5, "suspiciously empty exposition:\n{text}");
    assert!(!bucket_inf.is_empty() || hist_count.is_empty(), "counts without buckets");
    for (key, count) in &hist_count {
        let inf = bucket_inf
            .get(key)
            .unwrap_or_else(|| panic!("no +Inf bucket for {key}"));
        assert!(
            (inf - count).abs() < 0.5,
            "+Inf bucket {inf} must equal _count {count} for {key}"
        );
    }
}

/// `Σ phase_totals ≈ Σ latencies`: the decomposition covers the whole
/// request, with nothing double-counted and nothing unattributed.
fn assert_phases_cover_total(coord: &Coordinator, what: &str) {
    let s = coord.secure_summary();
    assert!(s.count > 0, "{what}: no requests observed");
    let total: f64 = s.mean_s * s.count as f64;
    let phase_sum: f64 = s.phase_totals_s.iter().sum();
    let tol = total * 0.05 + 0.02; // 5% + a fixed epsilon for timer jitter
    assert!(
        (phase_sum - total).abs() <= tol,
        "{what}: phase sum {phase_sum:.4}s vs total {total:.4}s exceeds 5% tolerance \
         (phases: {:?})",
        PHASES.iter().zip(s.phase_totals_s.iter()).collect::<Vec<_>>()
    );
}

/// Acceptance: the coordinator's exposition is well-formed and counts
/// exactly what was served.
#[test]
fn coordinator_metrics_exposition_is_well_formed() {
    let cfg = tiny();
    let w = random_weights(&cfg, 71);
    let c = Coordinator::start(cfg.clone(), w, None, BatcherConfig::default()).unwrap();
    for i in 0..3 {
        let r = c.infer_blocking(ModelInput::Tokens(tokens(&cfg, i)), EngineKind::Secure);
        assert!(r.error.is_none());
    }
    let text = c.render_metrics();
    assert_well_formed_exposition(&text, "coordinator");
    assert!(
        text.contains("secformer_requests_total{role=\"coordinator\",engine=\"secure\"} 3"),
        "{text}"
    );
    assert!(text.contains("secformer_uptime_seconds{role=\"coordinator\"}"), "{text}");
    assert!(text.contains("secformer_phase_seconds_total{role=\"coordinator\",phase=\"queue\"}"));
    c.shutdown();
}

/// Acceptance: party and dealer answer `metrics` over their framed
/// wires pre-handshake, in the same name schema (shared families like
/// `secformer_uptime_seconds`, distinguished only by the role label).
#[test]
fn party_and_dealer_answer_metrics_in_one_schema() {
    let cfg = tiny();
    let w = random_weights(&cfg, 73);

    let party_addr =
        spawn_party_host(cfg.clone(), Arc::new(shares1(&w)), None, PartyHostConfig::default())
            .expect("party host");
    let party = fetch_party_metrics(&party_addr.to_string(), None).expect("party metrics");
    assert_well_formed_exposition(&party, "party");
    assert!(party.contains("secformer_uptime_seconds{role=\"party\"}"), "{party}");
    assert!(party.contains("secformer_sessions_started_total{role=\"party\"} 0"), "{party}");

    let pools = PoolSet::start(
        &cfg,
        "obs-dealer",
        PoolConfig { target_depth: 2, producers: 1, ..PoolConfig::default() },
        false,
    );
    let dealer_addr = spawn_dealer(pools.clone()).expect("spawn dealer");
    let dealer = fetch_dealer_metrics(&dealer_addr.to_string(), None).expect("dealer metrics");
    assert_well_formed_exposition(&dealer, "dealer");
    assert!(dealer.contains("secformer_uptime_seconds{role=\"dealer\"}"), "{dealer}");
    assert!(dealer.contains("secformer_pool_depth{role=\"dealer\"}"), "{dealer}");
    // An unknown trace id is not an error — just an empty, terminated
    // JSONL body (a scrape must never kill a serving dealer).
    let trace = fetch_dealer_trace(&dealer_addr.to_string(), None, "no-such-label")
        .expect("dealer trace");
    assert!(trace.trim_end().ends_with("# EOF"), "{trace:?}");
    pools.stop();
}

/// Acceptance: per-phase latencies sum to total within 5% under the
/// pooled in-process topology.
#[test]
fn phase_totals_cover_latency_pooled() {
    let cfg = tiny();
    let w = random_weights(&cfg, 79);
    let mut serving = ServingConfig::pooled(1, 4);
    serving.plan_hidden = false;
    let c = Coordinator::start_with(cfg.clone(), w, None, BatcherConfig::default(), serving)
        .unwrap();
    for i in 0..4 {
        let r = c.infer_blocking(ModelInput::Tokens(tokens(&cfg, i)), EngineKind::Secure);
        assert!(r.error.is_none());
    }
    assert_phases_cover_total(&c, "pooled");
    // The transport phase exists but in-process "transport" is just
    // channel hand-off — it must not dominate.
    let s = c.secure_summary();
    assert!(s.phase_totals_s[4] < s.mean_s * s.count as f64, "{:?}", s.phase_totals_s);
    c.shutdown();
}

/// Acceptance: the decomposition survives a real remote party link —
/// transport-blocked time moves into the `transport` phase and the sum
/// still covers the total.
#[test]
fn phase_totals_cover_latency_remote_party() {
    let cfg = tiny();
    let w = random_weights(&cfg, 83);
    let addr = spawn_party_host(
        cfg.clone(),
        Arc::new(shares1(&w)),
        None,
        PartyHostConfig::default(),
    )
    .expect("party host");
    let c = Coordinator::start_with(
        cfg.clone(),
        w,
        None,
        BatcherConfig::default(),
        ServingConfig { peer_addr: Some(addr.to_string()), ..ServingConfig::default() },
    )
    .unwrap();
    for i in 0..3 {
        let r = c.infer_blocking(ModelInput::Tokens(tokens(&cfg, i)), EngineKind::Secure);
        assert!(r.error.is_none(), "{:?}", r.error);
    }
    assert_phases_cover_total(&c, "remote-party");
    let s = c.secure_summary();
    assert!(
        s.phase_totals_s[4] > 0.0,
        "a socket link must accrue transport-blocked time: {:?}",
        s.phase_totals_s
    );
    c.shutdown();
}

/// Acceptance: coordinator and party spans of ONE inference join on the
/// session label with no other correlation state.
#[test]
fn trace_spans_join_across_coordinator_and_party() {
    let cfg = tiny();
    let w = random_weights(&cfg, 89);
    let addr = spawn_party_host(
        cfg.clone(),
        Arc::new(shares1(&w)),
        None,
        PartyHostConfig::default(),
    )
    .expect("party host");
    let c = Coordinator::start_with(
        cfg.clone(),
        w,
        None,
        BatcherConfig::default(),
        ServingConfig { peer_addr: Some(addr.to_string()), ..ServingConfig::default() },
    )
    .unwrap();
    let r = c.infer_blocking(ModelInput::Tokens(tokens(&cfg, 1)), EngineKind::Secure);
    assert!(r.error.is_none(), "{:?}", r.error);

    // The coordinator minted the label; its own ring has the session.
    let spans = c.tracer().recent(64);
    let label = spans
        .iter()
        .find(|s| s.name == "session")
        .map(|s| s.trace.clone())
        .expect("coordinator recorded a session span");
    let coord_trace = c.render_trace(&label);
    assert!(coord_trace.contains("\"role\":\"coordinator\""), "{coord_trace}");
    assert!(coord_trace.contains("phase:"), "phases must be attributed: {coord_trace}");

    // The party host recorded under the SAME label — fetched over the
    // wire by label alone. The host's `session` span lands when its
    // worker unwinds, which can trail the coordinator's reply by a
    // moment; poll briefly instead of racing it.
    let deadline = std::time::Instant::now() + Duration::from_secs(3);
    let mut party_trace =
        fetch_party_trace(&addr.to_string(), None, &label).expect("party trace");
    while !party_trace.contains("\"name\":\"session\"")
        && std::time::Instant::now() < deadline
    {
        std::thread::sleep(Duration::from_millis(25));
        party_trace = fetch_party_trace(&addr.to_string(), None, &label).expect("party trace");
    }
    assert!(
        party_trace.contains("\"name\":\"session\""),
        "party must have joined session {label}: {party_trace}"
    );
    assert!(party_trace.contains("\"role\":\"party\""), "{party_trace}");
    assert!(party_trace.contains(&label), "{party_trace}");
    c.shutdown();
}

/// Acceptance: tracing is observation-only — logits, per-request comm
/// and the round/byte gauges are bit-identical with the tracer on or
/// off.
#[test]
fn tracing_on_off_is_bit_identical() {
    let cfg = tiny();
    let w = random_weights(&cfg, 97);
    let run = |trace: bool| {
        // Pin the session namespace: seeded offline randomness derives
        // from session labels, so bit-identity across two coordinator
        // instances needs label-aligned sessions (tests only — see the
        // `session_namespace` pad-reuse warning).
        let c = Coordinator::start_with(
            cfg.clone(),
            w.clone(),
            None,
            BatcherConfig::default(),
            ServingConfig {
                trace,
                session_namespace: Some("obs-parity".to_string()),
                ..ServingConfig::default()
            },
        )
        .unwrap();
        let mut out = Vec::new();
        for i in 0..3 {
            let r = c.infer_blocking(ModelInput::Tokens(tokens(&cfg, i)), EngineKind::Secure);
            assert!(r.error.is_none());
            out.push((r.logits, r.comm_bytes));
        }
        let s = c.secure_summary();
        let spans = c.tracer().len();
        c.shutdown();
        (out, s.rounds_per_request, s.offline_bytes, spans)
    };
    let (off, off_rounds, off_bytes, off_spans) = run(false);
    let (on, on_rounds, on_bytes, on_spans) = run(true);
    assert_eq!(off, on, "tracing must not perturb logits or comm");
    assert_eq!(off_rounds, on_rounds, "tracing must not add rounds");
    assert_eq!(off_bytes, on_bytes, "tracing must not add offline bytes");
    assert_eq!(off_spans, 0, "disabled tracer must record nothing");
    assert!(on_spans > 0, "enabled tracer must record spans");
}

/// Acceptance (generous CI bound): tracing overhead on the serving
/// path stays far from pathological — the 3% p50 bound is pinned by
/// `bench observability`; this test only guards against a catastrophic
/// regression (per-span allocation storms, lock convoys).
#[test]
fn tracing_overhead_is_bounded() {
    let cfg = tiny();
    let w = random_weights(&cfg, 101);
    let mean_latency = |trace: bool| {
        let c = Coordinator::start_with(
            cfg.clone(),
            w.clone(),
            None,
            BatcherConfig::default(),
            ServingConfig { trace, ..ServingConfig::default() },
        )
        .unwrap();
        // Warm-up outside the measurement.
        let _ = c.infer_blocking(ModelInput::Tokens(tokens(&cfg, 0)), EngineKind::Secure);
        let t0 = std::time::Instant::now();
        for i in 0..6 {
            let r = c.infer_blocking(ModelInput::Tokens(tokens(&cfg, i)), EngineKind::Secure);
            assert!(r.error.is_none());
        }
        let mean = t0.elapsed().as_secs_f64() / 6.0;
        c.shutdown();
        mean
    };
    let off = mean_latency(false);
    let on = mean_latency(true);
    assert!(
        on <= off * 2.0 + 0.05,
        "tracing overhead out of bounds: off {off:.4}s vs on {on:.4}s"
    );
}

/// Acceptance: the metrics stay consistent under concurrent load —
/// every request is counted exactly once and the exposition stays
/// well-formed while workers race.
#[test]
fn concurrent_load_keeps_metrics_consistent() {
    let cfg = tiny();
    let w = random_weights(&cfg, 103);
    let mut serving = ServingConfig::pooled(2, 8);
    serving.plan_hidden = false;
    let c = Arc::new(
        Coordinator::start_with(cfg.clone(), w, None, BatcherConfig::default(), serving)
            .unwrap(),
    );
    let clients = 4;
    let per_client = 3;
    std::thread::scope(|scope| {
        for t in 0..clients {
            let c = c.clone();
            let cfg = cfg.clone();
            scope.spawn(move || {
                for i in 0..per_client {
                    let r = c.infer_blocking(
                        ModelInput::Tokens(tokens(&cfg, (t * per_client + i) as u32)),
                        EngineKind::Secure,
                    );
                    assert!(r.error.is_none());
                }
            });
        }
    });
    let n = clients * per_client;
    let s = c.secure_summary();
    assert_eq!(s.count, n, "every request counted exactly once");
    assert_phases_cover_total(&c, "concurrent");
    let text = c.render_metrics();
    assert_well_formed_exposition(&text, "coordinator");
    assert!(
        text.contains(&format!(
            "secformer_requests_total{{role=\"coordinator\",engine=\"secure\"}} {n}"
        )),
        "{text}"
    );
    c.shutdown();
}

/// The label set of every sample line (the part before the value) — the
/// stable identity of an exposition, invariant across two scrapes taken
/// moments apart (values move; series do not).
fn series_names(text: &str) -> std::collections::BTreeSet<String> {
    text.lines()
        .filter(|l| !l.starts_with('#') && !l.is_empty())
        .filter_map(|l| l.rsplit_once(' ').map(|(s, _)| s.to_string()))
        .collect()
}

/// Reserve an ephemeral loopback port for a config field that takes an
/// address string (the listener binds moments later; the tiny reuse
/// window is the standard test trade-off).
fn free_addr() -> String {
    let l = std::net::TcpListener::bind("127.0.0.1:0").expect("reserve port");
    l.local_addr().expect("local addr").to_string()
}

/// GET with retries: the role binds its HTTP listener on its accept
/// thread, which can trail the spawn call by a moment.
fn http_get_retry(addr: &str, path: &str) -> (String, String) {
    let sock: std::net::SocketAddr = addr.parse().expect("addr");
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    loop {
        match secformer::obs::http::http_get(&sock, path) {
            Ok(r) => return r,
            Err(e) if std::time::Instant::now() >= deadline => {
                panic!("HTTP scrape of {addr} never came up: {e}")
            }
            Err(_) => std::thread::sleep(Duration::from_millis(25)),
        }
    }
}

/// Acceptance: an HTTP scrape of `/metrics` returns the same exposition
/// as the native-wire `metrics` command on all three roles, and non-GET
/// methods get 405 over real HTTP.
#[test]
fn http_scrape_matches_native_metrics_on_all_roles() {
    let cfg = tiny();
    let w = random_weights(&cfg, 151);

    // Coordinator: the process wires `--metrics-http` by handing the
    // listener a render closure over the coordinator handle — do the
    // same here, over real sockets.
    let c = Arc::new(
        Coordinator::start(cfg.clone(), w.clone(), None, BatcherConfig::default()).unwrap(),
    );
    let r = c.infer_blocking(ModelInput::Tokens(tokens(&cfg, 1)), EngineKind::Secure);
    assert!(r.error.is_none());
    let cc = c.clone();
    let srv = secformer::obs::MetricsHttpServer::start(
        "127.0.0.1:0",
        Arc::new(move || cc.render_metrics()),
    )
    .expect("coordinator http");
    let (status, body) =
        secformer::obs::http::http_get(&srv.local_addr(), "/metrics").expect("scrape");
    assert!(status.contains("200"), "{status}");
    assert_well_formed_exposition(&body, "coordinator");
    assert_eq!(series_names(&body), series_names(&c.render_metrics()));
    let (status, _) =
        secformer::obs::http::http_request(&srv.local_addr(), "POST", "/metrics").expect("post");
    assert!(status.contains("405"), "non-GET must be rejected: {status}");
    c.shutdown();

    // Party: `--metrics-http` travels in the host config; the accept
    // loop starts the listener itself.
    let party_http = free_addr();
    let party_addr = spawn_party_host(
        cfg.clone(),
        Arc::new(shares1(&w)),
        None,
        PartyHostConfig { metrics_http: Some(party_http.clone()), ..PartyHostConfig::default() },
    )
    .expect("party host");
    let (status, body) = http_get_retry(&party_http, "/metrics");
    assert!(status.contains("200"), "{status}");
    assert_well_formed_exposition(&body, "party");
    let native = fetch_party_metrics(&party_addr.to_string(), None).expect("party metrics");
    assert_eq!(series_names(&body), series_names(&native));

    // Dealer: same convention.
    let pools = PoolSet::start(
        &cfg,
        "http-dealer",
        PoolConfig { target_depth: 2, producers: 1, ..PoolConfig::default() },
        false,
    );
    let dealer_http = free_addr();
    let (dealer_addr, _stats) = spawn_dealer_with(
        pools.clone(),
        DealerConfig { metrics_http: Some(dealer_http.clone()), ..DealerConfig::default() },
    )
    .expect("spawn dealer");
    let (status, body) = http_get_retry(&dealer_http, "/metrics");
    assert!(status.contains("200"), "{status}");
    assert_well_formed_exposition(&body, "dealer");
    let native = fetch_dealer_metrics(&dealer_addr.to_string(), None).expect("dealer metrics");
    assert_eq!(series_names(&body), series_names(&native));
    pools.stop();
}

/// Every line of a JSONL export must be one complete object — no torn
/// or interleaved writes — and carry the expected role.
fn assert_jsonl_integrity(path: &std::path::Path, role: &str) -> Vec<String> {
    let body = std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("read {}: {e}", path.display()));
    let lines: Vec<String> = body.lines().map(str::to_string).collect();
    assert!(!lines.is_empty(), "empty export {}", path.display());
    for l in &lines {
        assert!(
            l.starts_with('{') && l.ends_with('}'),
            "torn line in {}: {l:?}",
            path.display()
        );
        assert!(l.contains(&format!("\"role\":\"{role}\"")), "{l:?}");
    }
    lines
}

/// Acceptance: `--trace-dir` export stays line-atomic under concurrent
/// load, and the ledger export lands beside it — every session label in
/// the ledger file joins a `session` span in the trace file.
#[test]
fn trace_dir_export_survives_concurrent_load() {
    let dir = std::env::temp_dir()
        .join(format!("secformer-obs-export-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = tiny();
    let w = random_weights(&cfg, 157);
    let mut serving = ServingConfig::pooled(2, 8);
    serving.plan_hidden = false;
    serving.trace_dir = Some(dir.to_string_lossy().into_owned());
    let c = Arc::new(
        Coordinator::start_with(cfg.clone(), w, None, BatcherConfig::default(), serving)
            .unwrap(),
    );
    let clients = 4;
    let per_client = 3;
    std::thread::scope(|scope| {
        for t in 0..clients {
            let c = c.clone();
            let cfg = cfg.clone();
            scope.spawn(move || {
                for i in 0..per_client {
                    let r = c.infer_blocking(
                        ModelInput::Tokens(tokens(&cfg, (t * per_client + i) as u32)),
                        EngineKind::Secure,
                    );
                    assert!(r.error.is_none());
                }
            });
        }
    });
    c.shutdown();
    let n = clients * per_client;

    let trace_lines = assert_jsonl_integrity(&dir.join("trace-coordinator.jsonl"), "coordinator");
    // One `session` span per executed chunk — the batcher may have
    // grouped concurrent requests, so chunks ∈ [1, n].
    let sessions = trace_lines
        .iter()
        .filter(|l| l.contains("\"name\":\"session\""))
        .count();
    assert!(
        (1..=n).contains(&sessions),
        "expected 1..={n} session spans, saw {sessions}"
    );

    let ledger_lines =
        assert_jsonl_integrity(&dir.join("ledger-coordinator.jsonl"), "coordinator");
    assert!(ledger_lines.iter().all(|l| l.contains("\"op\":")), "{ledger_lines:?}");
    // Every ledger session label joins a trace span by label alone.
    for l in &ledger_lines {
        let label = l
            .split("\"session\":\"")
            .nth(1)
            .and_then(|s| s.split('"').next())
            .unwrap_or_else(|| panic!("ledger row without session: {l:?}"));
        assert!(
            trace_lines.iter().any(|t| t.contains(label)),
            "ledger session {label} has no trace span"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Acceptance: ring evictions are surfaced IN the export — the JSONL
/// file keeps every span, and a `ring_dropped` meta line tells its
/// reader how far the in-memory `trace` query window has fallen behind.
#[test]
fn dropped_span_counter_lands_in_export() {
    let dir = std::env::temp_dir()
        .join(format!("secformer-obs-dropped-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let t = secformer::obs::Tracer::with_capacity("coordinator", 2, true);
    t.set_dir(&dir).expect("set_dir");
    for i in 0..5 {
        let _s = t.span(&format!("sess-{i}"), "session");
    }
    assert_eq!(t.dropped(), 3);
    let lines = assert_jsonl_integrity(&dir.join("trace-coordinator.jsonl"), "coordinator");
    assert_eq!(lines.iter().filter(|l| l.contains("\"name\":\"session\"")).count(), 5,
        "the export keeps every span");
    let drops: Vec<&String> =
        lines.iter().filter(|l| l.contains("\"meta\":\"ring_dropped\"")).collect();
    assert_eq!(drops.len(), 3, "one meta line per eviction: {lines:?}");
    assert!(drops.last().unwrap().contains("\"count\":3"), "{drops:?}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Acceptance: ledger rows join trace spans by session label across all
/// three roles — coordinator and party under the inference label, the
/// dealer under the bundle session it served.
#[test]
fn ledger_rows_join_trace_spans_across_roles() {
    let dir = std::env::temp_dir()
        .join(format!("secformer-obs-join-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = tiny();
    let w = random_weights(&cfg, 163);

    // Coordinator + remote party, one shared export directory (each
    // role writes its own role-suffixed files).
    let addr = spawn_party_host(
        cfg.clone(),
        Arc::new(shares1(&w)),
        None,
        PartyHostConfig {
            trace_dir: Some(dir.to_string_lossy().into_owned()),
            ..PartyHostConfig::default()
        },
    )
    .expect("party host");
    let c = Coordinator::start_with(
        cfg.clone(),
        w.clone(),
        None,
        BatcherConfig::default(),
        ServingConfig {
            peer_addr: Some(addr.to_string()),
            trace_dir: Some(dir.to_string_lossy().into_owned()),
            ..ServingConfig::default()
        },
    )
    .unwrap();
    let r = c.infer_blocking(ModelInput::Tokens(tokens(&cfg, 2)), EngineKind::Secure);
    assert!(r.error.is_none(), "{:?}", r.error);
    let label = c
        .tracer()
        .recent(64)
        .iter()
        .find(|s| s.name == "session")
        .map(|s| s.trace.clone())
        .expect("coordinator session span");
    c.shutdown();

    // The party's exports land when its session worker unwinds (the
    // files themselves exist from host startup — poll for content).
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    let party_ledger = dir.join("ledger-party.jsonl");
    while std::fs::read_to_string(&party_ledger)
        .map(|b| !b.contains(&label))
        .unwrap_or(true)
        && std::time::Instant::now() < deadline
    {
        std::thread::sleep(Duration::from_millis(25));
    }
    for (file, role) in [
        ("trace-coordinator.jsonl", "coordinator"),
        ("ledger-coordinator.jsonl", "coordinator"),
        ("trace-party.jsonl", "party"),
        ("ledger-party.jsonl", "party"),
    ] {
        let lines = assert_jsonl_integrity(&dir.join(file), role);
        assert!(
            lines.iter().any(|l| l.contains(&label)),
            "{file} must carry session {label}"
        );
    }

    // Dealer: serving one PULL records a trace span and a ledger row
    // under the served bundle's session label.
    let pools = PoolSet::start(
        &cfg,
        "join-dealer",
        PoolConfig { target_depth: 2, producers: 1, ..PoolConfig::default() },
        false,
    );
    let (dealer_addr, _stats) = spawn_dealer_with(
        pools.clone(),
        DealerConfig {
            trace_dir: Some(dir.to_string_lossy().into_owned()),
            ..DealerConfig::default()
        },
    )
    .expect("spawn dealer");
    let rp = RemotePool::connect(
        &dealer_addr.to_string(),
        &cfg,
        RemotePoolConfig { depth: 1, kinds: vec![PlanInput::Tokens], psk: None },
    )
    .expect("remote pool");
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    let bundle = loop {
        if let Some(b) = rp.pop(PlanInput::Tokens) {
            break b;
        }
        assert!(std::time::Instant::now() < deadline, "no bundle prefetched after 5s");
        std::thread::sleep(Duration::from_millis(25));
    };
    // The dealer ships the bundle BEFORE recording its span and ledger
    // row, so receipt does not order the export — poll for the label.
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    let dealer_ledger_path = dir.join("ledger-dealer.jsonl");
    while std::fs::read_to_string(&dealer_ledger_path)
        .map(|b| !b.contains(&bundle.session))
        .unwrap_or(true)
        && std::time::Instant::now() < deadline
    {
        std::thread::sleep(Duration::from_millis(25));
    }
    let dealer_trace = assert_jsonl_integrity(&dir.join("trace-dealer.jsonl"), "dealer");
    let dealer_ledger = assert_jsonl_integrity(&dealer_ledger_path, "dealer");
    assert!(
        dealer_trace.iter().any(|l| l.contains(&bundle.session) && l.contains("\"name\":\"pull\"")),
        "dealer pull span for {}: {dealer_trace:?}",
        bundle.session
    );
    assert!(
        dealer_ledger
            .iter()
            .any(|l| l.contains(&bundle.session) && l.contains("\"op\":\"bundle\"")),
        "dealer ledger row for {}: {dealer_ledger:?}",
        bundle.session
    );
    rp.stop();
    pools.stop();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Acceptance: the party-link heartbeat doubles as an RTT probe — an
/// idle link populates the last/EWMA gauges within a few heartbeats.
#[test]
fn party_link_rtt_gauge_populates() {
    let cfg = tiny();
    let w = random_weights(&cfg, 107);
    let addr = spawn_party_host(
        cfg.clone(),
        Arc::new(shares1(&w)),
        None,
        PartyHostConfig::default(),
    )
    .expect("party host");
    let s1 = Arc::new(shares1(&w));
    let opts = LinkOptions {
        heartbeat: Duration::from_millis(50),
        link_timeout: Duration::from_millis(2000),
    };
    let rp = RemoteParty::try_connect(&addr.to_string(), &cfg, &s1, None, opts)
        .expect("connect party link");
    // Idle past several heartbeats: each PING's PONG carries an RTT
    // sample into the gauges.
    let deadline = std::time::Instant::now() + Duration::from_secs(3);
    while rp.rtt_last_ms() == 0.0 && std::time::Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(rp.rtt_last_ms() > 0.0, "no RTT sample after 3s of idle heartbeats");
    assert!(rp.rtt_ewma_ms() > 0.0, "EWMA must seed from the first sample");
    rp.stop();
}
