//! Integration tests for the unified telemetry subsystem, pinning the
//! PR's acceptance criteria:
//!
//! 1. all three roles answer `metrics` with a well-formed Prometheus
//!    text exposition under ONE name schema (`secformer_*`, every
//!    sample labelled with its role);
//! 2. the phase decomposition is honest: per-phase latency totals sum
//!    to total measured latency within 5%, under both the pooled
//!    in-process topology and a real remote party link;
//! 3. spans of one inference join across coordinator and party by the
//!    session label alone — the trace id IS the label already on the
//!    wire;
//! 4. tracing is observation-only: logits, rounds and bytes are
//!    bit-identical with the tracer on or off, and the overhead stays
//!    bounded;
//! 5. metrics stay consistent under concurrent load.

use secformer::coordinator::{BatcherConfig, Coordinator, EngineKind, ServingConfig};
use secformer::coordinator::metrics::PHASES;
use secformer::core::rng::Xoshiro;
use secformer::nn::config::{Framework, ModelConfig};
use secformer::nn::model::ModelInput;
use secformer::nn::weights::{random_weights, share_weights, ShareMap, WeightMap};
use secformer::offline::pool::PoolConfig;
use secformer::offline::remote::{fetch_dealer_metrics, fetch_dealer_trace, spawn_dealer};
use secformer::offline::source::PoolSet;
use secformer::party::runtime::{
    fetch_party_metrics, fetch_party_trace, spawn_party_host, LinkOptions, PartyHostConfig,
    RemoteParty,
};
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

fn tiny() -> ModelConfig {
    ModelConfig::tiny(8, Framework::SecFormer)
}

fn tokens(cfg: &ModelConfig, shift: u32) -> Vec<u32> {
    (0..cfg.seq as u32).map(|i| (i + shift) % cfg.vocab as u32).collect()
}

/// The engine's fixed sharing seed: equal weights ⇒ equal share maps ⇒
/// a matching HELLO fingerprint between coordinator and party host.
fn shares1(w: &WeightMap) -> ShareMap {
    let (_, s1) = share_weights(w, &mut Xoshiro::seed_from(0x5EC0));
    s1
}

/// Structural validation of one Prometheus text exposition: every
/// sample line is `name{labels} value` with a `secformer_` name, the
/// expected `role` label and a parseable finite value; every histogram
/// bucket series is monotone with its `+Inf` bucket equal to `_count`;
/// the body ends with the `# EOF` terminator.
fn assert_well_formed_exposition(text: &str, role: &str) {
    assert!(text.ends_with("# EOF\n") || text.ends_with("# EOF"), "missing EOF: {text:?}");
    let mut samples = 0usize;
    let mut bucket_prev: Option<f64> = None;
    // `+Inf` bucket and `_count` per histogram series (keyed by the
    // series' label set, so multi-row families compare row-to-row).
    let mut bucket_inf: HashMap<String, f64> = HashMap::new();
    let mut hist_count: HashMap<String, f64> = HashMap::new();
    for line in text.lines() {
        if line.starts_with('#') || line.is_empty() {
            continue;
        }
        let (series, value) = line.rsplit_once(' ').unwrap_or_else(|| {
            panic!("sample line without value: {line:?}");
        });
        assert!(series.starts_with("secformer_"), "unprefixed sample: {line:?}");
        assert!(
            series.contains(&format!("role=\"{role}\"")),
            "sample without role label: {line:?}"
        );
        let v: f64 = value.parse().unwrap_or_else(|e| {
            panic!("unparseable value in {line:?}: {e}");
        });
        assert!(v.is_finite(), "non-finite sample: {line:?}");
        samples += 1;
        // Cumulative-bucket monotonicity within each histogram row; a
        // `+Inf` bucket closes the row and must equal that row's
        // `_count`.
        if series.contains("_bucket{") {
            if let Some(prev) = bucket_prev {
                assert!(v >= prev, "non-monotone bucket: {line:?}");
            }
            bucket_prev = Some(v);
            if series.contains("le=\"+Inf\"") {
                bucket_inf.insert(
                    series.replace(",le=\"+Inf\"", "").replace("le=\"+Inf\"", ""),
                    v,
                );
                bucket_prev = None; // the next row's series restarts
            }
        } else if series.contains("_count{") {
            hist_count.insert(series.replace("_count{", "_bucket{"), v);
        }
    }
    assert!(samples > 5, "suspiciously empty exposition:\n{text}");
    assert!(!bucket_inf.is_empty() || hist_count.is_empty(), "counts without buckets");
    for (key, count) in &hist_count {
        let inf = bucket_inf
            .get(key)
            .unwrap_or_else(|| panic!("no +Inf bucket for {key}"));
        assert!(
            (inf - count).abs() < 0.5,
            "+Inf bucket {inf} must equal _count {count} for {key}"
        );
    }
}

/// `Σ phase_totals ≈ Σ latencies`: the decomposition covers the whole
/// request, with nothing double-counted and nothing unattributed.
fn assert_phases_cover_total(coord: &Coordinator, what: &str) {
    let s = coord.secure_summary();
    assert!(s.count > 0, "{what}: no requests observed");
    let total: f64 = s.mean_s * s.count as f64;
    let phase_sum: f64 = s.phase_totals_s.iter().sum();
    let tol = total * 0.05 + 0.02; // 5% + a fixed epsilon for timer jitter
    assert!(
        (phase_sum - total).abs() <= tol,
        "{what}: phase sum {phase_sum:.4}s vs total {total:.4}s exceeds 5% tolerance \
         (phases: {:?})",
        PHASES.iter().zip(s.phase_totals_s.iter()).collect::<Vec<_>>()
    );
}

/// Acceptance: the coordinator's exposition is well-formed and counts
/// exactly what was served.
#[test]
fn coordinator_metrics_exposition_is_well_formed() {
    let cfg = tiny();
    let w = random_weights(&cfg, 71);
    let c = Coordinator::start(cfg.clone(), w, None, BatcherConfig::default()).unwrap();
    for i in 0..3 {
        let r = c.infer_blocking(ModelInput::Tokens(tokens(&cfg, i)), EngineKind::Secure);
        assert!(r.error.is_none());
    }
    let text = c.render_metrics();
    assert_well_formed_exposition(&text, "coordinator");
    assert!(
        text.contains("secformer_requests_total{role=\"coordinator\",engine=\"secure\"} 3"),
        "{text}"
    );
    assert!(text.contains("secformer_uptime_seconds{role=\"coordinator\"}"), "{text}");
    assert!(text.contains("secformer_phase_seconds_total{role=\"coordinator\",phase=\"queue\"}"));
    c.shutdown();
}

/// Acceptance: party and dealer answer `metrics` over their framed
/// wires pre-handshake, in the same name schema (shared families like
/// `secformer_uptime_seconds`, distinguished only by the role label).
#[test]
fn party_and_dealer_answer_metrics_in_one_schema() {
    let cfg = tiny();
    let w = random_weights(&cfg, 73);

    let party_addr =
        spawn_party_host(cfg.clone(), Arc::new(shares1(&w)), None, PartyHostConfig::default())
            .expect("party host");
    let party = fetch_party_metrics(&party_addr.to_string(), None).expect("party metrics");
    assert_well_formed_exposition(&party, "party");
    assert!(party.contains("secformer_uptime_seconds{role=\"party\"}"), "{party}");
    assert!(party.contains("secformer_sessions_started_total{role=\"party\"} 0"), "{party}");

    let pools = PoolSet::start(
        &cfg,
        "obs-dealer",
        PoolConfig { target_depth: 2, producers: 1, ..PoolConfig::default() },
        false,
    );
    let dealer_addr = spawn_dealer(pools.clone()).expect("spawn dealer");
    let dealer = fetch_dealer_metrics(&dealer_addr.to_string(), None).expect("dealer metrics");
    assert_well_formed_exposition(&dealer, "dealer");
    assert!(dealer.contains("secformer_uptime_seconds{role=\"dealer\"}"), "{dealer}");
    assert!(dealer.contains("secformer_pool_depth{role=\"dealer\"}"), "{dealer}");
    // An unknown trace id is not an error — just an empty, terminated
    // JSONL body (a scrape must never kill a serving dealer).
    let trace = fetch_dealer_trace(&dealer_addr.to_string(), None, "no-such-label")
        .expect("dealer trace");
    assert!(trace.trim_end().ends_with("# EOF"), "{trace:?}");
    pools.stop();
}

/// Acceptance: per-phase latencies sum to total within 5% under the
/// pooled in-process topology.
#[test]
fn phase_totals_cover_latency_pooled() {
    let cfg = tiny();
    let w = random_weights(&cfg, 79);
    let mut serving = ServingConfig::pooled(1, 4);
    serving.plan_hidden = false;
    let c = Coordinator::start_with(cfg.clone(), w, None, BatcherConfig::default(), serving)
        .unwrap();
    for i in 0..4 {
        let r = c.infer_blocking(ModelInput::Tokens(tokens(&cfg, i)), EngineKind::Secure);
        assert!(r.error.is_none());
    }
    assert_phases_cover_total(&c, "pooled");
    // The transport phase exists but in-process "transport" is just
    // channel hand-off — it must not dominate.
    let s = c.secure_summary();
    assert!(s.phase_totals_s[4] < s.mean_s * s.count as f64, "{:?}", s.phase_totals_s);
    c.shutdown();
}

/// Acceptance: the decomposition survives a real remote party link —
/// transport-blocked time moves into the `transport` phase and the sum
/// still covers the total.
#[test]
fn phase_totals_cover_latency_remote_party() {
    let cfg = tiny();
    let w = random_weights(&cfg, 83);
    let addr = spawn_party_host(
        cfg.clone(),
        Arc::new(shares1(&w)),
        None,
        PartyHostConfig::default(),
    )
    .expect("party host");
    let c = Coordinator::start_with(
        cfg.clone(),
        w,
        None,
        BatcherConfig::default(),
        ServingConfig { peer_addr: Some(addr.to_string()), ..ServingConfig::default() },
    )
    .unwrap();
    for i in 0..3 {
        let r = c.infer_blocking(ModelInput::Tokens(tokens(&cfg, i)), EngineKind::Secure);
        assert!(r.error.is_none(), "{:?}", r.error);
    }
    assert_phases_cover_total(&c, "remote-party");
    let s = c.secure_summary();
    assert!(
        s.phase_totals_s[4] > 0.0,
        "a socket link must accrue transport-blocked time: {:?}",
        s.phase_totals_s
    );
    c.shutdown();
}

/// Acceptance: coordinator and party spans of ONE inference join on the
/// session label with no other correlation state.
#[test]
fn trace_spans_join_across_coordinator_and_party() {
    let cfg = tiny();
    let w = random_weights(&cfg, 89);
    let addr = spawn_party_host(
        cfg.clone(),
        Arc::new(shares1(&w)),
        None,
        PartyHostConfig::default(),
    )
    .expect("party host");
    let c = Coordinator::start_with(
        cfg.clone(),
        w,
        None,
        BatcherConfig::default(),
        ServingConfig { peer_addr: Some(addr.to_string()), ..ServingConfig::default() },
    )
    .unwrap();
    let r = c.infer_blocking(ModelInput::Tokens(tokens(&cfg, 1)), EngineKind::Secure);
    assert!(r.error.is_none(), "{:?}", r.error);

    // The coordinator minted the label; its own ring has the session.
    let spans = c.tracer().recent(64);
    let label = spans
        .iter()
        .find(|s| s.name == "session")
        .map(|s| s.trace.clone())
        .expect("coordinator recorded a session span");
    let coord_trace = c.render_trace(&label);
    assert!(coord_trace.contains("\"role\":\"coordinator\""), "{coord_trace}");
    assert!(coord_trace.contains("phase:"), "phases must be attributed: {coord_trace}");

    // The party host recorded under the SAME label — fetched over the
    // wire by label alone. The host's `session` span lands when its
    // worker unwinds, which can trail the coordinator's reply by a
    // moment; poll briefly instead of racing it.
    let deadline = std::time::Instant::now() + Duration::from_secs(3);
    let mut party_trace =
        fetch_party_trace(&addr.to_string(), None, &label).expect("party trace");
    while !party_trace.contains("\"name\":\"session\"")
        && std::time::Instant::now() < deadline
    {
        std::thread::sleep(Duration::from_millis(25));
        party_trace = fetch_party_trace(&addr.to_string(), None, &label).expect("party trace");
    }
    assert!(
        party_trace.contains("\"name\":\"session\""),
        "party must have joined session {label}: {party_trace}"
    );
    assert!(party_trace.contains("\"role\":\"party\""), "{party_trace}");
    assert!(party_trace.contains(&label), "{party_trace}");
    c.shutdown();
}

/// Acceptance: tracing is observation-only — logits, per-request comm
/// and the round/byte gauges are bit-identical with the tracer on or
/// off.
#[test]
fn tracing_on_off_is_bit_identical() {
    let cfg = tiny();
    let w = random_weights(&cfg, 97);
    let run = |trace: bool| {
        // Pin the session namespace: seeded offline randomness derives
        // from session labels, so bit-identity across two coordinator
        // instances needs label-aligned sessions (tests only — see the
        // `session_namespace` pad-reuse warning).
        let c = Coordinator::start_with(
            cfg.clone(),
            w.clone(),
            None,
            BatcherConfig::default(),
            ServingConfig {
                trace,
                session_namespace: Some("obs-parity".to_string()),
                ..ServingConfig::default()
            },
        )
        .unwrap();
        let mut out = Vec::new();
        for i in 0..3 {
            let r = c.infer_blocking(ModelInput::Tokens(tokens(&cfg, i)), EngineKind::Secure);
            assert!(r.error.is_none());
            out.push((r.logits, r.comm_bytes));
        }
        let s = c.secure_summary();
        let spans = c.tracer().len();
        c.shutdown();
        (out, s.rounds_per_request, s.offline_bytes, spans)
    };
    let (off, off_rounds, off_bytes, off_spans) = run(false);
    let (on, on_rounds, on_bytes, on_spans) = run(true);
    assert_eq!(off, on, "tracing must not perturb logits or comm");
    assert_eq!(off_rounds, on_rounds, "tracing must not add rounds");
    assert_eq!(off_bytes, on_bytes, "tracing must not add offline bytes");
    assert_eq!(off_spans, 0, "disabled tracer must record nothing");
    assert!(on_spans > 0, "enabled tracer must record spans");
}

/// Acceptance (generous CI bound): tracing overhead on the serving
/// path stays far from pathological — the 3% p50 bound is pinned by
/// `bench observability`; this test only guards against a catastrophic
/// regression (per-span allocation storms, lock convoys).
#[test]
fn tracing_overhead_is_bounded() {
    let cfg = tiny();
    let w = random_weights(&cfg, 101);
    let mean_latency = |trace: bool| {
        let c = Coordinator::start_with(
            cfg.clone(),
            w.clone(),
            None,
            BatcherConfig::default(),
            ServingConfig { trace, ..ServingConfig::default() },
        )
        .unwrap();
        // Warm-up outside the measurement.
        let _ = c.infer_blocking(ModelInput::Tokens(tokens(&cfg, 0)), EngineKind::Secure);
        let t0 = std::time::Instant::now();
        for i in 0..6 {
            let r = c.infer_blocking(ModelInput::Tokens(tokens(&cfg, i)), EngineKind::Secure);
            assert!(r.error.is_none());
        }
        let mean = t0.elapsed().as_secs_f64() / 6.0;
        c.shutdown();
        mean
    };
    let off = mean_latency(false);
    let on = mean_latency(true);
    assert!(
        on <= off * 2.0 + 0.05,
        "tracing overhead out of bounds: off {off:.4}s vs on {on:.4}s"
    );
}

/// Acceptance: the metrics stay consistent under concurrent load —
/// every request is counted exactly once and the exposition stays
/// well-formed while workers race.
#[test]
fn concurrent_load_keeps_metrics_consistent() {
    let cfg = tiny();
    let w = random_weights(&cfg, 103);
    let mut serving = ServingConfig::pooled(2, 8);
    serving.plan_hidden = false;
    let c = Arc::new(
        Coordinator::start_with(cfg.clone(), w, None, BatcherConfig::default(), serving)
            .unwrap(),
    );
    let clients = 4;
    let per_client = 3;
    std::thread::scope(|scope| {
        for t in 0..clients {
            let c = c.clone();
            let cfg = cfg.clone();
            scope.spawn(move || {
                for i in 0..per_client {
                    let r = c.infer_blocking(
                        ModelInput::Tokens(tokens(&cfg, (t * per_client + i) as u32)),
                        EngineKind::Secure,
                    );
                    assert!(r.error.is_none());
                }
            });
        }
    });
    let n = clients * per_client;
    let s = c.secure_summary();
    assert_eq!(s.count, n, "every request counted exactly once");
    assert_phases_cover_total(&c, "concurrent");
    let text = c.render_metrics();
    assert_well_formed_exposition(&text, "coordinator");
    assert!(
        text.contains(&format!(
            "secformer_requests_total{{role=\"coordinator\",engine=\"secure\"}} {n}"
        )),
        "{text}"
    );
    c.shutdown();
}

/// Acceptance: the party-link heartbeat doubles as an RTT probe — an
/// idle link populates the last/EWMA gauges within a few heartbeats.
#[test]
fn party_link_rtt_gauge_populates() {
    let cfg = tiny();
    let w = random_weights(&cfg, 107);
    let addr = spawn_party_host(
        cfg.clone(),
        Arc::new(shares1(&w)),
        None,
        PartyHostConfig::default(),
    )
    .expect("party host");
    let s1 = Arc::new(shares1(&w));
    let opts = LinkOptions {
        heartbeat: Duration::from_millis(50),
        link_timeout: Duration::from_millis(2000),
    };
    let rp = RemoteParty::try_connect(&addr.to_string(), &cfg, &s1, None, opts)
        .expect("connect party link");
    // Idle past several heartbeats: each PING's PONG carries an RTT
    // sample into the gauges.
    let deadline = std::time::Instant::now() + Duration::from_secs(3);
    while rp.rtt_last_ms() == 0.0 && std::time::Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(rp.rtt_last_ms() > 0.0, "no RTT sample after 3s of idle heartbeats");
    assert!(rp.rtt_ewma_ms() > 0.0, "EWMA must seed from the first sample");
    rp.stop();
}
