//! Regression tests for the round-fused attention path (the tentpole
//! invariant of the batched protocol layer):
//!
//! 1. online rounds per encoder layer are independent of `cfg.heads`;
//! 2. fusion batches rounds without inflating byte volume (the only volume
//!    change is the *saving* from the shared Q/K/V mask opening);
//! 3. the fused network bill beats the unfused baseline by ≥ 2× on a
//!    BERT-base-style head count;
//! 4. fused and unfused paths both still match the plaintext reference.

use secformer::core::rng::Xoshiro;
use secformer::engine::{InferenceResult, OfflineMode, SecureModel};
use secformer::net::stats::{NetModel, OpCategory};
use secformer::nn::config::{Framework, ModelConfig};
use secformer::nn::model::{ref_forward, ModelInput};
use secformer::nn::weights::random_weights;

fn hidden_input(cfg: &ModelConfig, seed: u64) -> ModelInput {
    let mut rng = Xoshiro::seed_from(seed);
    ModelInput::Hidden((0..cfg.seq * cfg.hidden).map(|_| rng.normal() * 0.5).collect())
}

fn run(cfg: &ModelConfig, seed: u64) -> InferenceResult {
    let w = random_weights(cfg, seed);
    let input = hidden_input(cfg, seed + 1);
    SecureModel::new(cfg.clone(), &w, OfflineMode::Seeded).infer(&input)
}

#[test]
fn rounds_per_layer_independent_of_heads() {
    // Same model shape, different head splits: with fused attention the
    // per-head protocol work shares rounds, so the total online round
    // count must be identical at heads = 2 and heads = 4.
    for fw in [Framework::SecFormer, Framework::Crypten] {
        let mut c2 = ModelConfig::tiny(8, fw);
        c2.heads = 2;
        let c4 = ModelConfig::tiny(8, fw); // tiny default: 4 heads
        assert_eq!(c4.heads, 4);
        let r2 = run(&c2, 0xF00);
        let r4 = run(&c4, 0xF00);
        assert_eq!(
            r2.stats.total_rounds(),
            r4.stats.total_rounds(),
            "{fw:?}: rounds must not depend on head count"
        );
        assert_eq!(
            r2.stats.rounds_per_layer(c2.layers),
            r4.stats.rounds_per_layer(c4.layers),
        );
    }
}

#[test]
fn fusion_batches_rounds_without_inflating_volume() {
    let fused_cfg = ModelConfig::tiny(8, Framework::SecFormer);
    let mut unfused_cfg = fused_cfg.clone();
    unfused_cfg.fused_attention = false;
    let fused = run(&fused_cfg, 0xFA5);
    let unfused = run(&unfused_cfg, 0xFA5);

    // Round fusion is the whole point: strictly fewer rounds per layer.
    assert!(
        fused.stats.total_rounds() < unfused.stats.total_rounds(),
        "fused {} vs unfused {}",
        fused.stats.total_rounds(),
        unfused.stats.total_rounds()
    );

    // Batching opens the same masked operands in fewer exchanges, so the
    // per-category nonlinear volumes are untouched…
    for cat in [OpCategory::Softmax, OpCategory::Gelu, OpCategory::LayerNorm] {
        assert_eq!(
            fused.stats.bytes[cat as usize],
            unfused.stats.bytes[cat as usize],
            "{cat:?} volume must be unchanged by fusion"
        );
    }
    // …and the only total-volume change is the *saving* from opening the
    // shared Q/K/V left-operand mask once instead of three times:
    // 2·seq·hidden ring elements (8 bytes each) per encoder layer.
    let qkv_mask_saving =
        (fused_cfg.layers * 2 * fused_cfg.seq * fused_cfg.hidden * 8) as u64;
    assert_eq!(
        unfused.stats.total_bytes(),
        fused.stats.total_bytes() + qkv_mask_saving,
        "fusion must not add a single byte beyond the QKV mask sharing"
    );
}

#[test]
fn fused_network_bill_at_least_2x_cheaper_at_bert_base_head_count() {
    // BERT-base's head count (12) at scaled-down widths: the unfused path
    // pays per-head score/softmax/context rounds, the fused path a
    // head-independent constant, so the simulated-LAN network bill (the
    // rounds·rtt + bytes/bandwidth term that dominates the paper's
    // wall-clock) must improve by ≥ 2×.
    let mut fused_cfg = ModelConfig::tiny(8, Framework::SecFormer);
    fused_cfg.hidden = 48;
    fused_cfg.intermediate = 96;
    fused_cfg.heads = 12;
    let mut unfused_cfg = fused_cfg.clone();
    unfused_cfg.fused_attention = false;
    let fused = run(&fused_cfg, 0xBA5E);
    let unfused = run(&unfused_cfg, 0xBA5E);
    let lan = NetModel::paper_lan();
    let fused_net =
        lan.simulated_seconds(fused.stats.total_rounds(), fused.stats.total_bytes() * 2);
    let unfused_net = lan
        .simulated_seconds(unfused.stats.total_rounds(), unfused.stats.total_bytes() * 2);
    assert!(
        unfused_net >= 2.0 * fused_net,
        "LAN bill: fused {fused_net:.4}s vs unfused {unfused_net:.4}s"
    );
}

#[test]
fn fused_and_unfused_paths_match_reference() {
    // Fusion is a re-scheduling of the same protocol operations; both
    // paths must agree with the plaintext reference (and hence with each
    // other) within the engine's standing tolerance.
    let fused_cfg = ModelConfig::tiny(8, Framework::SecFormer);
    let mut unfused_cfg = fused_cfg.clone();
    unfused_cfg.fused_attention = false;
    let w = random_weights(&fused_cfg, 0xACC);
    let input = hidden_input(&fused_cfg, 0xACD);
    let expect = ref_forward(&fused_cfg, &w, &input);
    for cfg in [&fused_cfg, &unfused_cfg] {
        let got = SecureModel::new(cfg.clone(), &w, OfflineMode::Seeded).infer(&input);
        for i in 0..cfg.num_labels {
            assert!(
                (got.logits[i] - expect[i]).abs() < 0.15,
                "fused={} logit {i}: secure={} ref={}",
                cfg.fused_attention,
                got.logits[i],
                expect[i]
            );
        }
    }
}
