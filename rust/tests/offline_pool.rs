//! Integration tests for the offline precomputation subsystem:
//!
//! 1. the planner's manifest is *exact* — a real inference consumes a
//!    pregenerated bundle completely, with every (op, shape) matching,
//!    for both `fused_attention` paths and both input kinds;
//! 2. `OfflineMode::Pooled` is bit-identical to `OfflineMode::Dealer`
//!    with ZERO synchronous dealer round-trips online;
//! 3. a shallow pool blocks-then-resumes under sustained demand, and a
//!    stopped or mismatched pool falls back to seeded generation —
//!    results are never wrong.

use secformer::core::fixed::encode_vec;
use secformer::core::rng::Xoshiro;
use secformer::engine::{OfflineMode, SecureModel};
use secformer::net::transport::channel_pair;
use secformer::nn::config::{Framework, ModelConfig};
use secformer::nn::model::{bert_forward, ref_forward, InputShare, ModelInput};
use secformer::nn::weights::{random_weights, share_weights};
use secformer::offline::planner::{plan_demand, PlanInput};
use secformer::offline::pool::{generate_bundle, PoolConfig, TuplePool};
use secformer::offline::provider::{PooledProvider, PoolTelemetry};
use secformer::proto::ctx::PartyCtx;
use secformer::sharing::provider::CrGen;
use secformer::sharing::{reconstruct, share};
use std::sync::atomic::Ordering;
use std::sync::Arc;

fn hidden_input(cfg: &ModelConfig, seed: u64) -> ModelInput {
    let mut rng = Xoshiro::seed_from(seed);
    ModelInput::Hidden((0..cfg.seq * cfg.hidden).map(|_| rng.normal() * 0.5).collect())
}

fn token_input(cfg: &ModelConfig) -> ModelInput {
    ModelInput::Tokens((0..cfg.seq as u32).map(|i| i % cfg.vocab as u32).collect())
}

/// Share a model input the way the engine does (values arbitrary).
fn share_model_input(
    cfg: &ModelConfig,
    input: &ModelInput,
    rng: &mut Xoshiro,
) -> (InputShare, InputShare) {
    match input {
        ModelInput::Hidden(h) => {
            let (a, b) = share(&encode_vec(h), rng);
            (InputShare::Hidden(a), InputShare::Hidden(b))
        }
        ModelInput::Tokens(toks) => {
            let mut onehot = vec![0.0f64; cfg.seq * cfg.vocab];
            for (i, &t) in toks.iter().enumerate() {
                onehot[i * cfg.vocab + t as usize] = 1.0;
            }
            let (a, b) = share(&encode_vec(&onehot), rng);
            (InputShare::OneHot(a), InputShare::OneHot(b))
        }
    }
}

/// Run one inference where each party consumes a pregenerated bundle half
/// through a telemetry-instrumented `PooledProvider`. Returns the decoded
/// logits and both parties' telemetry.
fn run_pooled_manual(
    cfg: &ModelConfig,
    input: &ModelInput,
    session: &str,
) -> (Vec<f64>, Arc<PoolTelemetry>, Arc<PoolTelemetry>) {
    let kind = match input {
        ModelInput::Hidden(_) => PlanInput::Hidden,
        ModelInput::Tokens(_) => PlanInput::Tokens,
    };
    let manifest = plan_demand(cfg, kind);
    let (b0, b1) = generate_bundle(&mut CrGen::from_session(session), &manifest);

    let weights = random_weights(cfg, 0xBEEF);
    let mut rng = Xoshiro::seed_from(0xBEEF ^ 7);
    let (w0, w1) = share_weights(&weights, &mut rng);
    let (in0, in1) = share_model_input(cfg, input, &mut rng);

    let tel0 = Arc::new(PoolTelemetry::default());
    let tel1 = Arc::new(PoolTelemetry::default());
    let (peer0, peer1) = channel_pair();
    let fb = format!("{session}/fallback");
    let (out0, out1) = std::thread::scope(|scope| {
        let cfg0 = cfg.clone();
        let cfg1 = cfg.clone();
        let (fb0, fb1) = (fb.clone(), fb.clone());
        let (t0, t1) = (tel0.clone(), tel1.clone());
        let w0 = &w0;
        let w1 = &w1;
        let h0 = scope.spawn(move || {
            let prov = Box::new(PooledProvider::new(b0, 0, &fb0).with_telemetry(t0));
            let mut ctx = PartyCtx::new(0, Box::new(peer0), prov, 0xAA);
            bert_forward(&mut ctx, &cfg0, w0, &in0)
        });
        let h1 = scope.spawn(move || {
            let prov = Box::new(PooledProvider::new(b1, 1, &fb1).with_telemetry(t1));
            let mut ctx = PartyCtx::new(1, Box::new(peer1), prov, 0xBB);
            bert_forward(&mut ctx, &cfg1, w1, &in1)
        });
        (h0.join().expect("party 0"), h1.join().expect("party 1"))
    });
    let logits = secformer::core::fixed::decode_vec(&reconstruct(&out0, &out1));

    // The reference forward needs the same weights/input.
    let expect = ref_forward(cfg, &weights, input);
    assert_eq!(logits.len(), expect.len());
    for i in 0..logits.len() {
        assert!(
            (logits[i] - expect[i]).abs() < 0.2,
            "logit {i}: pooled={} ref={}",
            logits[i],
            expect[i]
        );
    }
    (logits, tel0, tel1)
}

#[test]
fn planned_manifest_is_consumed_exactly_fused_and_unfused() {
    // Every (op, shape) pop is checked inside PooledProvider; a full
    // drain with zero fallbacks therefore proves planned == consumed.
    for fused in [true, false] {
        let mut cfg = ModelConfig::tiny(8, Framework::SecFormer);
        cfg.fused_attention = fused;
        let manifest = plan_demand(&cfg, PlanInput::Hidden);
        let input = hidden_input(&cfg, 0x11);
        let (_, tel0, tel1) = run_pooled_manual(&cfg, &input, "exact-h");
        for (who, tel) in [("p0", &tel0), ("p1", &tel1)] {
            assert!(
                !tel.fell_back.load(Ordering::Relaxed),
                "fused={fused} {who}: demand diverged from plan"
            );
            assert_eq!(
                tel.pool_served.load(Ordering::Relaxed),
                manifest.reqs.len() as u64,
                "fused={fused} {who}: served-request count"
            );
            assert_eq!(
                tel.leftover.load(Ordering::Relaxed),
                0,
                "fused={fused} {who}: bundle must drain completely"
            );
        }
    }
}

#[test]
fn planned_manifest_is_consumed_exactly_for_token_inputs() {
    let cfg = ModelConfig::tiny(8, Framework::SecFormer);
    let manifest = plan_demand(&cfg, PlanInput::Tokens);
    let input = token_input(&cfg);
    let (_, tel0, tel1) = run_pooled_manual(&cfg, &input, "exact-t");
    for tel in [&tel0, &tel1] {
        assert!(!tel.fell_back.load(Ordering::Relaxed));
        assert_eq!(tel.pool_served.load(Ordering::Relaxed), manifest.reqs.len() as u64);
        assert_eq!(tel.leftover.load(Ordering::Relaxed), 0);
    }
}

#[test]
fn pooled_is_bit_identical_to_dealer_with_zero_dealer_roundtrips() {
    let cfg = ModelConfig::tiny(8, Framework::SecFormer);
    let w = random_weights(&cfg, 7);
    let input = hidden_input(&cfg, 8);

    let mut dealer = SecureModel::new(cfg.clone(), &w, OfflineMode::Dealer);
    dealer.set_session_label("parity");
    // AES-PRF pool (fast=false) with the dealer model's label as prefix:
    // bundle n replays exactly the dealer streams of session n.
    let manifest = plan_demand(&cfg, PlanInput::Hidden);
    let pool = TuplePool::start(
        manifest,
        "parity",
        PoolConfig { target_depth: 2, producers: 1, fast: false, ..PoolConfig::default() },
    );
    let mut pooled = SecureModel::new_pooled(cfg.clone(), &w, pool.clone());
    pooled.set_session_label("parity");

    let a = dealer.infer(&input);
    let b = pooled.infer(&input);
    assert_eq!(a.logits, b.logits, "pooled must be bit-identical to dealer");
    // Same online phase, different offline transport.
    assert_eq!(a.stats.total_rounds(), b.stats.total_rounds());
    assert_eq!(a.stats.total_bytes(), b.stats.total_bytes());
    assert!(a.stats.offline_msgs > 0, "dealer mode round-trips to T");
    assert_eq!(b.stats.offline_msgs, 0, "pooled mode must never consult T online");
    assert!(b.stats.offline_bytes > 0, "pooled offline bytes are accounted");
    // And a second session stays aligned (bundle 2 vs dealer session 2).
    let a2 = dealer.infer(&input);
    let b2 = pooled.infer(&input);
    assert_eq!(a2.logits, b2.logits);
    pool.stop();
}

#[test]
fn shallow_pool_blocks_then_resumes_never_wrong() {
    let cfg = ModelConfig::tiny(8, Framework::SecFormer);
    let w = random_weights(&cfg, 21);
    let input = hidden_input(&cfg, 22);
    let expect = ref_forward(&cfg, &w, &input);
    let manifest = plan_demand(&cfg, PlanInput::Hidden);
    // Depth-1 pool: back-to-back inferences must wait for the producer to
    // regenerate between sessions — and always answer correctly.
    let pool = TuplePool::start(
        manifest,
        "shallow",
        PoolConfig { target_depth: 1, producers: 1, ..PoolConfig::default() },
    );
    let mut model = SecureModel::new_pooled(cfg.clone(), &w, pool.clone());
    for round in 0..3 {
        let r = model.infer(&input);
        assert_eq!(r.stats.offline_msgs, 0, "round {round}");
        for i in 0..cfg.num_labels {
            assert!(
                (r.logits[i] - expect[i]).abs() < 0.2,
                "round {round} logit {i}: {} vs {}",
                r.logits[i],
                expect[i]
            );
        }
    }
    let snap = pool.snapshot();
    assert_eq!(snap.consumed, 3);
    pool.stop();

    // Stopped pool: pop_bundle yields None and the engine falls back to
    // synchronized seeded generation — still correct, still dealer-free.
    let r = model.infer(&input);
    assert_eq!(r.stats.offline_msgs, 0);
    for i in 0..cfg.num_labels {
        assert!((r.logits[i] - expect[i]).abs() < 0.2, "post-stop logit {i}");
    }
}

#[test]
fn mismatched_bundle_falls_back_never_wrong() {
    let cfg = ModelConfig::tiny(8, Framework::SecFormer);
    let w = random_weights(&cfg, 31);
    // Pool planned for token inputs, but the request carries hidden
    // states: the very first pop mismatches and the session must complete
    // on the synchronized seeded fallback.
    let manifest = plan_demand(&cfg, PlanInput::Tokens);
    let pool = TuplePool::start(
        manifest,
        "mismatch",
        PoolConfig { target_depth: 1, producers: 1, ..PoolConfig::default() },
    );
    let mut model = SecureModel::new_pooled(cfg.clone(), &w, pool.clone());
    let input = hidden_input(&cfg, 32);
    let expect = ref_forward(&cfg, &w, &input);
    let r = model.infer(&input);
    for i in 0..cfg.num_labels {
        assert!(
            (r.logits[i] - expect[i]).abs() < 0.2,
            "logit {i}: {} vs {}",
            r.logits[i],
            expect[i]
        );
    }
    assert_eq!(r.stats.offline_msgs, 0);
    let snap = pool.snapshot();
    assert!(snap.misses >= 1, "in-session fallback must count as a pool miss");
    pool.stop();
}
