//! Property-based tests (hand-rolled randomized trials — proptest is not in
//! the offline crate set; the Python side uses hypothesis for the same
//! role). Each test sweeps random shapes/values and asserts an invariant.

use secformer::core::fixed::{decode, encode, encode_vec};
use secformer::core::rng::Xoshiro;
use secformer::proto::harness::{run_pair_raw_out, run_pair_with_inputs};
use secformer::proto::{bits, gelu, prim, softmax};
use secformer::sharing::{reconstruct, share};

#[test]
fn prop_share_reconstruct_roundtrip() {
    let mut rng = Xoshiro::seed_from(1);
    for trial in 0..50 {
        let n = 1 + (rng.next_u64() % 200) as usize;
        let vals: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
        let (s0, s1) = share(&vals, &mut rng);
        assert_eq!(reconstruct(&s0, &s1), vals, "trial {trial}");
    }
}

#[test]
fn prop_fixed_point_encoding_additive_homomorphism() {
    let mut rng = Xoshiro::seed_from(2);
    for _ in 0..200 {
        let a = rng.uniform(-1e5, 1e5);
        let b = rng.uniform(-1e5, 1e5);
        let sum = decode(encode(a).wrapping_add(encode(b)));
        assert!((sum - (a + b)).abs() < 2.0 / 65536.0 + 1e-9, "a={a} b={b}");
    }
}

#[test]
fn prop_secure_mul_random_shapes_and_magnitudes() {
    let mut rng = Xoshiro::seed_from(3);
    for trial in 0..8 {
        let n = 1 + (rng.next_u64() % 64) as usize;
        let mag = 10f64.powi((trial % 4) as i32);
        let x: Vec<f64> = (0..n).map(|_| rng.uniform(-mag, mag)).collect();
        let y: Vec<f64> = (0..n).map(|_| rng.uniform(-mag, mag)).collect();
        let got = run_pair_with_inputs(&x, &y, |c, a, b| prim::mul(c, a, b));
        for i in 0..n {
            let expect = x[i] * y[i];
            let tol = expect.abs() * 1e-4 + mag * 3.0 / 65536.0 + 1e-4;
            assert!((got[i] - expect).abs() < tol, "n={n} mag={mag} i={i}");
        }
    }
}

#[test]
fn prop_secure_matmul_matches_f64() {
    let mut rng = Xoshiro::seed_from(4);
    for _ in 0..5 {
        let (m, k, n) = (
            1 + (rng.next_u64() % 6) as usize,
            1 + (rng.next_u64() % 6) as usize,
            1 + (rng.next_u64() % 6) as usize,
        );
        let x: Vec<f64> = (0..m * k).map(|_| rng.uniform(-3.0, 3.0)).collect();
        let y: Vec<f64> = (0..k * n).map(|_| rng.uniform(-3.0, 3.0)).collect();
        let got = run_pair_with_inputs(&x, &y, |c, a, b| prim::matmul(c, a, b, m, k, n));
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for p in 0..k {
                    acc += x[i * k + p] * y[p * n + j];
                }
                assert!(
                    (got[i * n + j] - acc).abs() < 1e-2,
                    "({m},{k},{n}) @ ({i},{j})"
                );
            }
        }
    }
}

#[test]
fn prop_comparison_total_order_consistency() {
    // lt(x,y) and lt(y,x) can't both be 1, and x<y ⇔ ¬(y≤x).
    let mut rng = Xoshiro::seed_from(5);
    let n = 64;
    let x: Vec<f64> = (0..n).map(|_| rng.uniform(-100.0, 100.0)).collect();
    let y: Vec<f64> = (0..n).map(|_| rng.uniform(-100.0, 100.0)).collect();
    let a = run_pair_raw_out(&x, &y, |c, xs, ys| bits::lt(c, xs, ys));
    let b = run_pair_raw_out(&y, &x, |c, ys, xs| bits::lt(c, ys, xs));
    for i in 0..n {
        assert!(a[i] <= 1 && b[i] <= 1);
        assert!(!(a[i] == 1 && b[i] == 1), "both lt true at {i}");
        assert_eq!(a[i] == 1, x[i] < y[i], "x={} y={}", x[i], y[i]);
    }
}

#[test]
fn prop_2quad_is_a_distribution() {
    // Rows sum to 1 and entries are nonnegative for any input.
    let mut rng = Xoshiro::seed_from(6);
    for _ in 0..4 {
        let rows = 1 + (rng.next_u64() % 4) as usize;
        let n = 2 + (rng.next_u64() % 16) as usize;
        let x: Vec<f64> = (0..rows * n).map(|_| rng.uniform(-4.0, 4.0)).collect();
        let got = run_pair_with_inputs(&x, &x, |c, a, _| {
            softmax::softmax_2quad_secformer(c, a, rows, n)
        });
        for r in 0..rows {
            let row = &got[r * n..(r + 1) * n];
            let sum: f64 = row.iter().sum();
            assert!((sum - 1.0).abs() < 0.03, "row {r} sums to {sum}");
            assert!(row.iter().all(|&v| v > -0.01), "negative prob in row {r}");
        }
    }
}

#[test]
fn prop_gelu_secformer_bounded_error_everywhere() {
    // |Π_GeLU(x) − GeLU(x)| stays below the paper's worst case across the
    // whole fixed-point-safe domain, including far outside the segment.
    let mut rng = Xoshiro::seed_from(7);
    let x: Vec<f64> = (0..256).map(|_| rng.uniform(-30.0, 30.0)).collect();
    let got = run_pair_with_inputs(&x, &x, |c, a, _| gelu::gelu_secformer(c, a));
    for i in 0..x.len() {
        let err = (got[i] - gelu::gelu_exact(x[i])).abs();
        assert!(err < 0.05, "x={} err={err}", x[i]);
    }
}

#[test]
fn prop_trunc_error_bounded() {
    // SecureML local truncation: ±1 LSB w.h.p. over random shares.
    let mut rng = Xoshiro::seed_from(8);
    for _ in 0..500 {
        let v = rng.uniform(-1e4, 1e4);
        let double_scale = ((v * 65536.0 * 65536.0) as i64) as u64;
        let (s0, s1) = share(&[double_scale], &mut rng);
        let t0 = secformer::core::fixed::trunc_share(s0[0], 0, 16);
        let t1 = secformer::core::fixed::trunc_share(s1[0], 1, 16);
        let rec = decode(t0.wrapping_add(t1));
        assert!((rec - v).abs() < 3.0 / 65536.0 + 1e-9, "v={v} rec={rec}");
    }
}

#[test]
fn prop_matmul_parallel_matches_serial_random_shapes() {
    // Row sharding must be bit-identical to the serial kernel for ANY
    // shape, not just the fixed one pinned in core/tensor.rs — wrapped
    // sums are order-independent, so a divergence means a sharding bug
    // (mis-sliced chunk edges), not a rounding difference.
    use secformer::core::kernel::{matmul_ring_with, Kernel, KernelConfig, SCALAR, SIMD};
    let serial = KernelConfig { max_threads: 1, par_threshold_ops: usize::MAX };
    let mut rng = Xoshiro::seed_from(10);
    for trial in 0..24 {
        let m = 1 + (rng.next_u64() % 130) as usize;
        let k = 1 + (rng.next_u64() % 64) as usize;
        let n = 1 + (rng.next_u64() % 48) as usize;
        let a: Vec<u64> = (0..m * k).map(|_| rng.next_u64()).collect();
        let b: Vec<u64> = (0..k * n).map(|_| rng.next_u64()).collect();
        for kern in [&SCALAR as &dyn Kernel, &SIMD] {
            let mut ser = vec![0u64; m * n];
            matmul_ring_with(kern, serial, &a, &b, &mut ser, m, k, n);
            let threads = 2 + (rng.next_u64() % 7) as usize;
            let forced = KernelConfig { max_threads: threads, par_threshold_ops: 1 };
            let mut par = vec![0u64; m * n];
            matmul_ring_with(kern, forced, &a, &b, &mut par, m, k, n);
            assert_eq!(
                par,
                ser,
                "trial {trial}: {} ({m},{k},{n}) threads={threads}",
                kern.name()
            );
        }
    }
}

#[test]
fn prop_matmul_overflow_heavy_all_max_operands() {
    // All-u64::MAX operands force maximal wrapping on every product and
    // accumulation. MAX·MAX ≡ 1 (mod 2^64), so each output element is
    // exactly k — an independent closed form both backends (and the
    // threaded path) must hit bit-for-bit.
    use secformer::core::kernel::{matmul_ring_with, Kernel, KernelConfig, SCALAR, SIMD};
    for (m, k, n) in [(1usize, 1usize, 1usize), (3, 5, 7), (2, 129, 9), (17, 31, 13)] {
        let a = vec![u64::MAX; m * k];
        let b = vec![u64::MAX; k * n];
        for kern in [&SCALAR as &dyn Kernel, &SIMD] {
            for cfg in [
                KernelConfig { max_threads: 1, par_threshold_ops: usize::MAX },
                KernelConfig { max_threads: 4, par_threshold_ops: 1 },
            ] {
                let mut c = vec![0u64; m * n];
                matmul_ring_with(kern, cfg, &a, &b, &mut c, m, k, n);
                assert!(
                    c.iter().all(|&v| v == k as u64),
                    "{} ({m},{k},{n}) threads={}: expected all {k}",
                    kern.name(),
                    cfg.max_threads
                );
            }
        }
    }
}

#[test]
fn prop_boolean_and_arithmetic_shares_consistent() {
    // encode_vec → share → reconstruct is exact for representable values.
    let mut rng = Xoshiro::seed_from(9);
    let vals: Vec<f64> = (0..100).map(|_| (rng.next_u64() % 1000) as f64 / 16.0).collect();
    let enc = encode_vec(&vals);
    let (s0, s1) = share(&enc, &mut rng);
    let rec = reconstruct(&s0, &s1);
    assert_eq!(rec, enc);
}
