//! Integration tests for the protocol-attribution cost ledger, pinning
//! the PR's acceptance criteria:
//!
//! 1. for fused and unfused attention, B ∈ {1, 8}, pooled and
//!    remote-party topologies, the per-op measured round count equals
//!    the `proto/cost.rs` analytic projection EXACTLY and measured
//!    bits/element stay within 10% of the projection;
//! 2. the attribution is a partition: Σ per-row ledger bytes equals the
//!    engine's `CommStats` total wire bytes exactly, and likewise for
//!    rounds — no unattributed traffic, nothing double-counted;
//! 3. the ledger observes without perturbing: logits, rounds and bytes
//!    are bit-identical with the ledger attached or not.
//!
//! The exactness in (2) is by construction, not coincidence: the
//! session ledger hooks the same party-0 `PartyCtx::exchange` funnel
//! that `CommStats` counts, so every recorded byte lands in exactly one
//! op row (or `other`).

use secformer::core::rng::Xoshiro;
use secformer::engine::{OfflineMode, SecureModel};
use secformer::nn::config::{Framework, ModelConfig};
use secformer::nn::model::ModelInput;
use secformer::nn::weights::{random_weights, share_weights, ShareMap, WeightMap};
use secformer::obs::ledger::{CostModelCheck, Ledger, OpStat};
use secformer::obs::ROLE_COORDINATOR;
use secformer::offline::pool::PoolConfig;
use secformer::offline::source::{BundleSource, PoolSet};
use secformer::party::runtime::{spawn_party_host, PartyHostConfig};
use std::collections::BTreeMap;
use std::sync::Arc;

fn tiny(fused: bool) -> ModelConfig {
    let mut cfg = ModelConfig::tiny(8, Framework::SecFormer);
    cfg.fused_attention = fused;
    cfg
}

fn tokens(cfg: &ModelConfig, shift: u32) -> Vec<u32> {
    (0..cfg.seq as u32).map(|i| (i + shift) % cfg.vocab as u32).collect()
}

/// The engine's fixed sharing seed: equal weights ⇒ equal share maps ⇒
/// a matching HELLO fingerprint between coordinator and party host.
fn shares1(w: &WeightMap) -> ShareMap {
    let (_, s1) = share_weights(w, &mut Xoshiro::seed_from(0x5EC0));
    s1
}

/// Σ (bytes, rounds) over the RAW path-keyed table. Raw rows partition
/// the wire traffic; the rollup does not (a parent op and its nested
/// child both claim the child's rounds).
fn raw_totals(rows: &BTreeMap<String, OpStat>) -> (u64, u64) {
    rows.values().fold((0, 0), |(b, r), s| (b + s.bytes, r + s.rounds))
}

/// Run one inference (B=1) or one homogeneous batch (B=8) with a fresh
/// ledger attached, then assert the acceptance criteria for this
/// (topology, attention, batch) cell.
fn run_and_check(model: &mut SecureModel, cfg: &ModelConfig, batch: usize, what: &str) {
    let ledger = Ledger::new(ROLE_COORDINATOR, true);
    model.set_ledger(Some(ledger.clone()));
    let stats = if batch == 1 {
        model.infer(&ModelInput::Tokens(tokens(cfg, 3))).stats
    } else {
        let inputs: Vec<ModelInput> =
            (0..batch).map(|i| ModelInput::Tokens(tokens(cfg, i as u32))).collect();
        let r = model.infer_batch(&inputs);
        assert_eq!(r.chunks, 1, "{what}: a homogeneous B={batch} batch must share one schedule");
        r.stats
    };
    assert_eq!(ledger.sessions_absorbed(), 1, "{what}: one session, one absorb");
    assert_eq!(ledger.dropped(), 0, "{what}: nothing dropped");

    // (2) The partition invariant, exact on both axes. `record_op`-only
    // rows (share/reconstruct wall-clock) add no rounds/bytes, so they
    // cannot break it.
    let rows = ledger.aggregate();
    let (sum_bytes, sum_rounds) = raw_totals(&rows);
    assert_eq!(
        sum_bytes,
        stats.total_bytes(),
        "{what}: Σ ledger row bytes must equal CommStats wire bytes exactly"
    );
    assert_eq!(
        sum_rounds,
        stats.total_rounds(),
        "{what}: Σ ledger row rounds must equal CommStats rounds exactly"
    );

    // (1) Measured vs analytic, per op. Rounds exact; bytes within 10%
    // where the model defines a per-element volume.
    let checks = CostModelCheck::new(cfg.seq, cfg.hidden).check(&rows);
    assert!(!checks.is_empty(), "{what}: reconciliation produced no ops");
    let names: Vec<&str> = checks.iter().map(|c| c.op).collect();
    for need in ["matmul", "softmax", "gelu", "layernorm"] {
        assert!(names.contains(&need), "{what}: op {need} missing from {names:?}");
    }
    for c in &checks {
        assert_eq!(
            c.rounds_delta(),
            0,
            "{what}/{}: measured {} rounds vs analytic {} over {} calls",
            c.op,
            c.measured_rounds,
            c.expected_rounds,
            c.calls
        );
        assert!(
            c.bytes_within(0.10),
            "{what}/{}: measured {:.1} bits/elem vs analytic {:?} exceeds 10%",
            c.op,
            c.measured_bits_per_elem,
            c.expected_bits_per_elem
        );
    }
}

/// Both batch cells of one (topology, attention) pane against a pooled
/// in-process bundle source.
fn pooled_pane(fused: bool, seed: u64) {
    let cfg = tiny(fused);
    let w = random_weights(&cfg, seed);
    let pools = PoolSet::start_with_buckets(
        &cfg,
        "ledger-pool",
        PoolConfig { target_depth: 2, producers: 1, ..PoolConfig::default() },
        false,
        &[1, 8],
    );
    pools.warm(1);
    let mut m = SecureModel::new_pooled(cfg.clone(), &w, pools.clone());
    m.set_session_label("ledger-pool");
    m.set_batch_buckets(&[1, 8]);
    let pane = if fused { "pooled/fused" } else { "pooled/unfused" };
    run_and_check(&mut m, &cfg, 1, &format!("{pane}/B=1"));
    run_and_check(&mut m, &cfg, 8, &format!("{pane}/B=8"));
    pools.stop();
}

/// Both batch cells of one (topology, attention) pane against a real
/// remote party host over a socket.
fn remote_pane(fused: bool, seed: u64) {
    let cfg = tiny(fused);
    let w = random_weights(&cfg, seed);
    let addr = spawn_party_host(
        cfg.clone(),
        Arc::new(shares1(&w)),
        None,
        PartyHostConfig::default(),
    )
    .expect("party host");
    let mut m = SecureModel::new(cfg.clone(), &w, OfflineMode::Seeded);
    m.connect_remote_peer(&addr.to_string(), None).expect("connect remote party");
    let pane = if fused { "remote/fused" } else { "remote/unfused" };
    run_and_check(&mut m, &cfg, 1, &format!("{pane}/B=1"));
    run_and_check(&mut m, &cfg, 8, &format!("{pane}/B=8"));
}

#[test]
fn cost_model_reconciles_pooled_fused() {
    pooled_pane(true, 113);
}

#[test]
fn cost_model_reconciles_pooled_unfused() {
    pooled_pane(false, 127);
}

#[test]
fn cost_model_reconciles_remote_fused() {
    remote_pane(true, 131);
}

#[test]
fn cost_model_reconciles_remote_unfused() {
    remote_pane(false, 137);
}

/// Acceptance: the ledger is observation-only — logits, rounds and
/// bytes are bit-identical with the ledger attached or absent, and a
/// disabled ledger mints no session tables at all.
#[test]
fn ledger_on_off_is_bit_identical() {
    let cfg = tiny(true);
    let w = random_weights(&cfg, 139);
    let run = |ledger: Option<Arc<Ledger>>| {
        let mut m = SecureModel::new(cfg.clone(), &w, OfflineMode::Seeded);
        // Pin the session namespace: seeded offline randomness derives
        // from session labels, so bit-identity across two models needs
        // label-aligned sessions.
        m.set_session_label("ledger-parity");
        m.set_ledger(ledger);
        let r = m.infer(&ModelInput::Tokens(tokens(&cfg, 5)));
        (r.logits, r.stats.total_rounds(), r.stats.total_bytes())
    };
    let off = run(None);
    let disabled_ledger = Ledger::new(ROLE_COORDINATOR, false);
    let disabled = run(Some(disabled_ledger.clone()));
    let enabled_ledger = Ledger::new(ROLE_COORDINATOR, true);
    let on = run(Some(enabled_ledger.clone()));
    assert_eq!(off, disabled, "a disabled ledger must not perturb the inference");
    assert_eq!(off, on, "an enabled ledger must not perturb the inference");
    assert_eq!(disabled_ledger.sessions_absorbed(), 0, "disabled ledger mints no sessions");
    assert!(disabled_ledger.aggregate().is_empty(), "disabled ledger stays empty");
    assert_eq!(enabled_ledger.sessions_absorbed(), 1);
}

/// The role aggregate accumulates across sessions and the per-session
/// ring serves each session's own rows under its label.
#[test]
fn aggregate_accumulates_and_sessions_stay_separable() {
    let cfg = tiny(true);
    let w = random_weights(&cfg, 149);
    let mut m = SecureModel::new(cfg.clone(), &w, OfflineMode::Seeded);
    m.set_session_label("ledger-ring");
    let ledger = Ledger::new(ROLE_COORDINATOR, true);
    m.set_ledger(Some(ledger.clone()));
    let a = m.infer(&ModelInput::Tokens(tokens(&cfg, 1)));
    let one = raw_totals(&ledger.aggregate());
    let b = m.infer(&ModelInput::Tokens(tokens(&cfg, 2)));
    let two = raw_totals(&ledger.aggregate());
    assert_eq!(ledger.sessions_absorbed(), 2);
    assert_eq!(two.0, one.0 * 2, "identical schedules must double the byte aggregate");
    assert_eq!(two.1, one.1 * 2, "identical schedules must double the round aggregate");
    assert_ne!(a.session, b.session, "sessions are distinct");
    for r in [&a, &b] {
        let rows = ledger
            .session_rows(&r.session)
            .unwrap_or_else(|| panic!("ring must retain session {}", r.session));
        let (bytes, rounds) = raw_totals(&rows);
        assert_eq!(bytes, r.stats.total_bytes(), "per-session rows partition that session");
        assert_eq!(rounds, r.stats.total_rounds());
    }
    assert!(ledger.session_rows("no-such-session").is_none());
}
