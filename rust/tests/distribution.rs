//! Integration tests for the offline distribution subsystem
//! (dealer-serve + RemotePool + disk spool), pinning the PR's
//! acceptance criteria:
//!
//! 1. serving against a standalone dealer over TCP is **bit-identical**
//!    to in-process `OfflineMode::Pooled`, with zero online dealer
//!    round-trips;
//! 2. a coordinator restarted over a populated spool directory reaches
//!    pool hit-rate 1.0 **without regenerating** a single bundle;
//! 3. the degradation contract survives distribution: losing the dealer
//!    never produces wrong results.

use secformer::coordinator::{BatcherConfig, Coordinator, EngineKind, ServingConfig};
use secformer::engine::SecureModel;
use secformer::nn::config::{Framework, ModelConfig};
use secformer::nn::model::{ref_forward, ModelInput};
use secformer::nn::weights::random_weights;
use secformer::offline::planner::{plan_demand, PlanInput};
use secformer::offline::pool::{PoolConfig, TuplePool};
use secformer::offline::remote::{spawn_dealer, RemotePool, RemotePoolConfig};
use secformer::offline::source::{BundleSource, PoolSet};
use secformer::offline::spool::{SpoolConfig, SpooledSource};
use std::path::PathBuf;
use std::sync::Arc;

fn tiny() -> ModelConfig {
    ModelConfig::tiny(8, Framework::SecFormer)
}

fn tokens(cfg: &ModelConfig, shift: u32) -> Vec<u32> {
    (0..cfg.seq as u32).map(|i| (i + shift) % cfg.vocab as u32).collect()
}

fn temp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "secformer-dist-{tag}-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Acceptance: `serve --dealer-addr` against a `dealer-serve` process is
/// bit-identical to in-process `OfflineMode::Pooled` — same namespace,
/// same weights, same requests ⇒ exactly equal logits.
#[test]
fn remote_coordinator_bit_identical_to_inprocess_pooled() {
    let cfg = tiny();
    let w = random_weights(&cfg, 41);
    let n = 2;

    let mut local_cfg = ServingConfig::pooled(1, 4);
    local_cfg.plan_hidden = false;
    local_cfg.session_namespace = Some("dist-par".to_string());
    let local = Coordinator::start_with(
        cfg.clone(),
        w.clone(),
        None,
        BatcherConfig::default(),
        local_cfg,
    )
    .unwrap();

    // The dealer generates under the SAME pool prefix the in-process
    // coordinator derives from its namespace, so bundle n carries the
    // same session label — that is the whole alignment contract.
    let dealer_pools = PoolSet::start(
        &cfg,
        "coord-pool-dist-par",
        PoolConfig { target_depth: 8, producers: 1, ..PoolConfig::default() },
        false,
    );
    let addr = spawn_dealer(dealer_pools.clone()).expect("spawn dealer");
    let mut remote_cfg = ServingConfig::pooled(1, 4);
    remote_cfg.plan_hidden = false;
    remote_cfg.session_namespace = Some("dist-par".to_string());
    remote_cfg.dealer_addr = Some(addr.to_string());
    let remote = Coordinator::start_with(
        cfg.clone(),
        w.clone(),
        None,
        BatcherConfig::default(),
        remote_cfg,
    )
    .unwrap();

    for i in 0..n {
        let input = ModelInput::Tokens(tokens(&cfg, i));
        let a = local.infer_blocking(input.clone(), EngineKind::Secure);
        let b = remote.infer_blocking(input, EngineKind::Secure);
        assert_eq!(
            a.logits, b.logits,
            "request {i}: remote dealer must be bit-identical to in-process pool"
        );
    }
    let ps = remote.pool_snapshot().expect("remote coordinator has a source");
    assert_eq!(ps.consumed, n as u64);
    local.shutdown();
    remote.shutdown();
    dealer_pools.stop();
}

/// Engine-level parity: a RemotePool-backed model matches a local
/// TuplePool-backed model bit-for-bit AND keeps `offline_msgs == 0` —
/// zero synchronous dealer round-trips during the online phase.
#[test]
fn remote_engine_runs_with_zero_online_dealer_roundtrips() {
    let cfg = tiny();
    let w = random_weights(&cfg, 43);
    let input = ModelInput::Tokens(tokens(&cfg, 3));

    let dealer_pools = PoolSet::start(
        &cfg,
        "dist-eng",
        PoolConfig { target_depth: 4, producers: 1, ..PoolConfig::default() },
        false,
    );
    let addr = spawn_dealer(dealer_pools.clone()).expect("spawn dealer");
    let remote_pool = RemotePool::connect(
        &addr.to_string(),
        &cfg,
        RemotePoolConfig { depth: 2, kinds: vec![PlanInput::Tokens], psk: None },
    )
    .expect("connect");

    let local_pool = TuplePool::start(
        plan_demand(&cfg, PlanInput::Tokens),
        "dist-eng",
        PoolConfig { target_depth: 4, producers: 1, ..PoolConfig::default() },
    );

    let mut remote_model = SecureModel::new_pooled(cfg.clone(), &w, remote_pool.clone());
    remote_model.set_session_label("dist-eng-m");
    let mut local_model = SecureModel::new_pooled(cfg.clone(), &w, local_pool.clone());
    local_model.set_session_label("dist-eng-m");

    let r = remote_model.infer(&input);
    let l = local_model.infer(&input);
    assert_eq!(r.logits, l.logits, "remote bundles must replay local streams");
    assert_eq!(r.stats.offline_msgs, 0, "online phase must never consult a dealer");
    assert!(r.stats.offline_bytes > 0, "prefetched bundle bytes are accounted");
    assert_eq!(r.stats.total_bytes(), l.stats.total_bytes());

    remote_pool.stop();
    local_pool.stop();
    dealer_pools.stop();
}

/// Acceptance: a coordinator restarted with a populated `--spool-dir`
/// reaches pool hit-rate 1.0 without regenerating bundles.
#[test]
fn spooled_coordinator_restart_full_hit_rate_without_regeneration() {
    let cfg = tiny();
    let w = random_weights(&cfg, 47);
    let n: usize = 3;
    let dir = temp_dir("restart");

    // "First life": populate the spool (bounded generation, all
    // persisted), then shut everything down — the simulated crash point.
    {
        let feeder = PoolSet::start(
            &cfg,
            "dist-spool",
            PoolConfig {
                target_depth: n,
                producers: 1,
                max_bundles: Some(n as u64),
                ..PoolConfig::default()
            },
            false,
        );
        let spool = SpooledSource::open(
            &dir,
            Some(feeder as Arc<dyn BundleSource>),
            SpoolConfig { depth: n, ..SpoolConfig::default() },
        )
        .expect("populate spool");
        spool.wait_spooled(n);
        spool.stop();
    }

    // "Second life": a fresh coordinator over the same directory, with
    // in-process production bounded to ZERO — disk is the only source.
    let mut serving = ServingConfig::pooled(1, n);
    serving.plan_hidden = false;
    serving.warm_bundles = 0;
    serving.pool_max_bundles = Some(0);
    serving.spool_dir = Some(dir.to_string_lossy().into_owned());
    let coord =
        Coordinator::start_with(cfg.clone(), w.clone(), None, BatcherConfig::default(), serving)
            .unwrap();
    for i in 0..n {
        let reply = coord
            .infer_blocking(ModelInput::Tokens(tokens(&cfg, i as u32)), EngineKind::Secure);
        assert!(reply.logits.iter().all(|v| v.is_finite()));
        assert_eq!(reply.logits.len(), cfg.num_labels);
    }
    let ps = coord.pool_snapshot().expect("spooled coordinator has a source");
    assert_eq!(ps.produced, 0, "restart must not regenerate a single bundle");
    assert_eq!(ps.hits, n as u64);
    assert_eq!(ps.misses, 0);
    let s = coord.secure_summary();
    assert!(
        (s.pool_hit_rate - 1.0).abs() < 1e-9,
        "hit rate {} after warm restart",
        s.pool_hit_rate
    );
    assert!(s.offline_bytes > 0, "spooled bundles are accounted as offline bytes");
    coord.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Degradation: when the dealer's pools are exhausted mid-stream the
/// coordinator keeps answering — correctly — on the seeded fallback.
#[test]
fn dealer_loss_degrades_but_stays_correct() {
    let cfg = tiny();
    let w = random_weights(&cfg, 53);
    // The dealer can hand out exactly ONE bundle, then errors out.
    let dealer_pools = PoolSet::start(
        &cfg,
        "dist-loss",
        PoolConfig {
            target_depth: 2,
            producers: 1,
            max_bundles: Some(1),
            ..PoolConfig::default()
        },
        false,
    );
    let addr = spawn_dealer(dealer_pools.clone()).expect("spawn dealer");
    let remote_pool = RemotePool::connect(
        &addr.to_string(),
        &cfg,
        RemotePoolConfig { depth: 2, kinds: vec![PlanInput::Tokens], psk: None },
    )
    .expect("connect");
    let mut model = SecureModel::new_pooled(cfg.clone(), &w, remote_pool.clone());

    let input = ModelInput::Tokens(tokens(&cfg, 5));
    let expect = ref_forward(&cfg, &w, &input);
    for round in 0..3 {
        let r = model.infer(&input);
        assert_eq!(r.stats.offline_msgs, 0, "round {round}");
        for i in 0..cfg.num_labels {
            assert!(
                (r.logits[i] - expect[i]).abs() < 0.2,
                "round {round} logit {i}: {} vs {}",
                r.logits[i],
                expect[i]
            );
        }
    }
    remote_pool.stop();
    dealer_pools.stop();
}
