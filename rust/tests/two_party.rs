//! Two-party runtime parity: an engine driving a remote `party-serve`
//! host over a real localhost TCP socket must be **bit-identical** to
//! the in-process thread engine — same logits, same rounds, same
//! volume — for both input kinds, both attention paths and every
//! offline mode, with zero dealer round-trips in pooled mode.
//!
//! Alignment recipe (mirrors the deployment docs): both processes load
//! the same weights (the fixed sharing seed then gives equal share
//! maps, hence a matching HELLO fingerprint), the engines use the same
//! session label, and in pooled mode the coordinator's and the host's
//! pools use the same prefix (bundle generation is a pure function of
//! the session label, so both sides independently derive the same
//! correlated randomness; the start/ack exchange matches the halves by
//! label).

use secformer::core::rng::Xoshiro;
use secformer::engine::{OfflineMode, PeerRuntime, SecureModel};
use secformer::nn::config::{Framework, ModelConfig};
use secformer::nn::model::ModelInput;
use secformer::nn::weights::{random_weights, share_weights, WeightMap};
use secformer::offline::pool::PoolConfig;
use secformer::offline::source::{BundleSource, PoolSet};
use secformer::party::runtime::{spawn_party_host, PartyHostConfig, RemoteParty};
use std::sync::Arc;

fn tiny(fused: bool) -> ModelConfig {
    let mut cfg = ModelConfig::tiny(8, Framework::SecFormer);
    cfg.fused_attention = fused;
    cfg
}

fn hidden_input(cfg: &ModelConfig, seed: u64) -> ModelInput {
    let mut rng = Xoshiro::seed_from(seed);
    ModelInput::Hidden((0..cfg.seq * cfg.hidden).map(|_| rng.normal() * 0.5).collect())
}

fn token_input(cfg: &ModelConfig) -> ModelInput {
    ModelInput::Tokens((0..cfg.seq as u32).map(|i| i % cfg.vocab as u32).collect())
}

fn shares1(w: &WeightMap) -> secformer::nn::weights::ShareMap {
    // The engine's fixed sharing seed: equal weights ⇒ equal shares.
    let (_, s1) = share_weights(w, &mut Xoshiro::seed_from(0x5EC0));
    s1
}

fn pool_set(cfg: &ModelConfig, prefix: &str) -> Arc<PoolSet> {
    PoolSet::start(
        cfg,
        prefix,
        PoolConfig { target_depth: 4, producers: 1, ..PoolConfig::default() },
        true,
    )
}

fn assert_bit_identical(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: logit count");
    for i in 0..a.len() {
        assert!(a[i].is_finite(), "{what}: logit {i} not finite");
        assert_eq!(
            a[i].to_bits(),
            b[i].to_bits(),
            "{what}: logit {i} differs: in-process={} remote={}",
            a[i],
            b[i]
        );
    }
}

/// Build the in-process twin and the remote pair (coordinator-side
/// model + party host), session-aligned on `label`/`prefix`.
fn pooled_pair(cfg: &ModelConfig, w: &WeightMap, prefix: &str, label: &str) -> (SecureModel, SecureModel) {
    let mut local = SecureModel::new_pooled(cfg.clone(), w, pool_set(cfg, prefix));
    local.set_session_label(label);

    let addr = spawn_party_host(
        cfg.clone(),
        Arc::new(shares1(w)),
        Some(pool_set(cfg, prefix) as Arc<dyn BundleSource>),
        PartyHostConfig::default(),
    )
    .expect("spawn party host");
    let mut remote = SecureModel::new_pooled(cfg.clone(), w, pool_set(cfg, prefix));
    remote.set_session_label(label);
    remote
        .connect_remote_peer(&addr.to_string(), None)
        .expect("connect to party host");
    (local, remote)
}

fn assert_pooled_parity(cfg: &ModelConfig, prefix: &str, label: &str, weight_seed: u64) {
    let w = random_weights(cfg, weight_seed);
    let (mut local, mut remote) = pooled_pair(cfg, &w, prefix, label);
    for (name, input) in [
        ("tokens", token_input(cfg)),
        ("hidden", hidden_input(cfg, 5)),
    ] {
        let a = local.infer(&input);
        let b = remote.infer(&input);
        assert_bit_identical(&a.logits, &b.logits, name);
        assert_eq!(
            b.stats.offline_msgs, 0,
            "{name}: pooled remote session must run with zero dealer round-trips"
        );
        assert_eq!(a.stats.offline_msgs, 0, "{name}: in-process twin too");
        assert!(b.stats.offline_bytes > 0, "{name}: prefetched bundle must be charged");
        assert_eq!(
            a.stats.offline_bytes, b.stats.offline_bytes,
            "{name}: identical bundles ⇒ identical offline accounting"
        );
        assert_eq!(a.stats.total_rounds(), b.stats.total_rounds(), "{name}: rounds");
        assert_eq!(a.stats.total_bytes(), b.stats.total_bytes(), "{name}: volume");
    }
}

#[test]
fn remote_pooled_is_bit_identical_fused() {
    assert_pooled_parity(&tiny(true), "twop-f-pool", "twop-f", 21);
}

#[test]
fn remote_pooled_is_bit_identical_unfused() {
    assert_pooled_parity(&tiny(false), "twop-u-pool", "twop-u", 22);
}

#[test]
fn remote_seeded_and_dealer_match_in_process() {
    let cfg = tiny(true);
    let w = random_weights(&cfg, 33);
    for (name, mode) in [("seeded", OfflineMode::Seeded), ("dealer", OfflineMode::Dealer)] {
        let label = format!("twop-{name}");
        let mut local = SecureModel::new(cfg.clone(), &w, mode);
        local.set_session_label(&label);
        let addr = spawn_party_host(
            cfg.clone(),
            Arc::new(shares1(&w)),
            None,
            PartyHostConfig::default(),
        )
        .expect("spawn party host");
        let mut remote = SecureModel::new(cfg.clone(), &w, mode);
        remote.set_session_label(&label);
        remote
            .connect_remote_peer(&addr.to_string(), None)
            .expect("connect to party host");
        let input = hidden_input(&cfg, 9);
        let a = local.infer(&input);
        let b = remote.infer(&input);
        assert_bit_identical(&a.logits, &b.logits, name);
        assert_eq!(
            a.stats.offline_msgs, b.stats.offline_msgs,
            "{name}: same label ⇒ same dealer transcript"
        );
        assert_eq!(a.stats.offline_bytes, b.stats.offline_bytes, "{name}");
        if mode == OfflineMode::Dealer {
            assert!(b.stats.offline_msgs > 0, "dealer mode runs S1↔T on the party host");
        }
    }
}

#[test]
fn pooled_remote_without_host_pool_degrades_to_seeded_parity() {
    // The party host has NO bundle source: the start/ack exchange must
    // land both sides on the synchronized seeded stream — which is
    // exactly what an in-process SEEDED engine with the same label
    // runs. Correctness survives the degradation bit-for-bit.
    let cfg = tiny(true);
    let w = random_weights(&cfg, 77);
    let label = "twop-deg";
    let mut seeded_twin = SecureModel::new(cfg.clone(), &w, OfflineMode::Seeded);
    seeded_twin.set_session_label(label);

    let addr = spawn_party_host(
        cfg.clone(),
        Arc::new(shares1(&w)),
        None, // no source on the host
        PartyHostConfig::default(),
    )
    .expect("spawn party host");
    let mut remote = SecureModel::new_pooled(cfg.clone(), &w, pool_set(&cfg, "twop-deg-pool"));
    remote.set_session_label(label);
    remote
        .connect_remote_peer(&addr.to_string(), None)
        .expect("connect to party host");

    let input = token_input(&cfg);
    let a = seeded_twin.infer(&input);
    let b = remote.infer(&input);
    assert_bit_identical(&a.logits, &b.logits, "degraded pooled session");
    assert_eq!(b.stats.offline_msgs, 0);
    assert_eq!(
        b.stats.offline_bytes, 0,
        "no bundle was used on either side, so none may be charged"
    );
}

#[test]
fn concurrent_sessions_multiplex_one_connection() {
    // Several engines share ONE RemoteParty connection; their sessions
    // interleave on the socket. Each must still match its in-process
    // twin exactly (per-session framing keeps the streams apart).
    let cfg = tiny(true);
    let w = random_weights(&cfg, 55);
    let s1 = shares1(&w);
    let addr = spawn_party_host(
        cfg.clone(),
        Arc::new(s1.clone()),
        None,
        PartyHostConfig::default(),
    )
    .expect("spawn party host");
    let rp = RemoteParty::connect(&addr.to_string(), &cfg, &s1, None).expect("connect");

    std::thread::scope(|scope| {
        for t in 0..3u64 {
            let cfg = cfg.clone();
            let w = w.clone();
            let rp = rp.clone();
            scope.spawn(move || {
                let label = format!("twop-mux-{t}");
                let mut local = SecureModel::new(cfg.clone(), &w, OfflineMode::Seeded);
                local.set_session_label(&label);
                let mut remote = SecureModel::new(cfg.clone(), &w, OfflineMode::Seeded);
                remote.set_session_label(&label);
                remote.set_peer_runtime(PeerRuntime::Remote(rp));
                for round in 0..2u64 {
                    let input = hidden_input(&cfg, 100 + t * 10 + round);
                    let a = local.infer(&input);
                    let b = remote.infer(&input);
                    assert_bit_identical(
                        &a.logits,
                        &b.logits,
                        &format!("mux thread {t} round {round}"),
                    );
                }
            });
        }
    });
    rp.stop();
}
