//! Fault-injection harness: the serving stack must be fail-*recover*,
//! not fail-stop. Every scenario routes the coordinator↔party link
//! through a [`ChaosProxy`] and kills it at a different point in the
//! protocol — mid-round, mid-handshake, between batches — then asserts
//! the recovery contract:
//!
//! * every submitted request gets either a correct (finite) logit
//!   vector or a clean typed [`SessionError`] reply — none are lost,
//!   no worker thread dies;
//! * the supervisor's reconnect counter and the batcher's retry
//!   counter tick, and `link_up` settles back to `true`;
//! * a retried session is cryptographically independent of the dead
//!   one: fresh session label, fresh input shares, fresh pad bundle
//!   (`retry_mints_fresh_label_and_consumes_fresh_bundle` pins it);
//! * the party host reaps every churned connection (no session or
//!   connection leak across 100 dropped dials).
//!
//! Scenario tests iterate fixed seeds [1, 2, 3] so CI exercises three
//! sever timings deterministically.

use secformer::coordinator::batcher::{
    BatcherConfig, Coordinator, EngineKind, InferenceReply, ServingConfig,
};
use secformer::core::rng::Xoshiro;
use secformer::engine::{OfflineMode, PeerRuntime, SecureModel};
use secformer::net::fault::ChaosProxy;
use secformer::nn::config::{Framework, ModelConfig};
use secformer::nn::model::ModelInput;
use secformer::nn::weights::{random_weights, share_weights, ShareMap, WeightMap};
use secformer::offline::planner::PlanInput;
use secformer::offline::pool::{PoolConfig, PoolSnapshot, SessionBundle};
use secformer::offline::source::{BundleSource, PoolSet};
use secformer::net::error::SessionError;
use secformer::party::runtime::{
    fetch_party_metrics, spawn_party_host, spawn_party_host_stats, LinkOptions, PartyHostConfig,
    RemoteParty,
};
use secformer::party::supervisor::{PartyLinkSupervisor, RedialPolicy};
use std::io::Write;
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

fn tiny() -> ModelConfig {
    ModelConfig::tiny(8, Framework::SecFormer)
}

/// The engine's fixed sharing seed: equal weights ⇒ equal share maps ⇒
/// a matching HELLO fingerprint between coordinator and host.
fn shares1(w: &WeightMap) -> ShareMap {
    let (_, s1) = share_weights(w, &mut Xoshiro::seed_from(0x5EC0));
    s1
}

fn token_input(cfg: &ModelConfig, seed: u64) -> ModelInput {
    ModelInput::Tokens(
        (0..cfg.seq as u32).map(|i| (i + seed as u32) % cfg.vocab as u32).collect(),
    )
}

/// Tight link policy so fault tests detect death in tens of
/// milliseconds instead of the production multi-second defaults.
fn fast_link() -> LinkOptions {
    LinkOptions {
        heartbeat: Duration::from_millis(100),
        link_timeout: Duration::from_millis(1000),
    }
}

fn spawn_host(cfg: &ModelConfig, w: &WeightMap) -> std::net::SocketAddr {
    spawn_party_host(cfg.clone(), Arc::new(shares1(w)), None, PartyHostConfig::default())
        .expect("party host")
}

/// A coordinator whose party link runs through the chaos proxy, with a
/// generous retry budget and the fast link policy.
fn chaos_coordinator(cfg: &ModelConfig, w: &WeightMap, proxy: &ChaosProxy) -> Coordinator {
    Coordinator::start_with(
        cfg.clone(),
        w.clone(),
        None,
        BatcherConfig::default(),
        ServingConfig {
            peer_addr: Some(proxy.addr().to_string()),
            session_retries: 4,
            party_heartbeat_ms: 100,
            link_timeout_ms: 1000,
            ..ServingConfig::default()
        },
    )
    .expect("coordinator over chaos proxy")
}

fn assert_clean_reply(r: &InferenceReply, nl: usize, what: &str) {
    match &r.error {
        None => {
            assert_eq!(r.logits.len(), nl, "{what}: logit count for request {}", r.id);
            for (i, v) in r.logits.iter().enumerate() {
                assert!(v.is_finite(), "{what}: logit {i} of request {} not finite", r.id);
            }
        }
        Some(_) => {
            // A typed failure is a legal outcome — but it must be a
            // clean one: no half-results.
            assert!(r.logits.is_empty(), "{what}: error reply carries logits");
        }
    }
}

/// Sever the link while a stream of requests is in flight: every
/// request is answered (retried to success or a typed error), the
/// recovery counters tick, and the workers survive to serve more.
#[test]
fn mid_round_sever_loses_no_requests() {
    for seed in [1u64, 2, 3] {
        let cfg = tiny();
        let w = random_weights(&cfg, 13);
        let host_addr = spawn_host(&cfg, &w);
        let proxy = ChaosProxy::start(&host_addr.to_string()).expect("proxy");
        let coord = chaos_coordinator(&cfg, &w, &proxy);

        let (tx, rx) = std::sync::mpsc::channel();
        let total = 10usize;
        // Seed-dependent sever point: early, mid and late in the stream.
        let sever_at = 1 + (seed as usize % 3) * 3;
        let mut ids = Vec::with_capacity(total);
        for i in 0..total {
            ids.push(coord.submit(token_input(&cfg, seed + i as u64), EngineKind::Secure, tx.clone()));
            if i == sever_at {
                proxy.sever_all();
            }
        }
        drop(tx);

        let mut replies = Vec::with_capacity(total);
        for _ in 0..total {
            let r = match rx.recv_timeout(Duration::from_secs(60)) {
                Ok(r) => r,
                Err(_) => panic!("seed {seed}: request lost (no reply within 60s)"),
            };
            assert_clean_reply(&r, cfg.num_labels, "mid-round sever");
            replies.push(r);
        }
        let mut got: Vec<u64> = replies.iter().map(|r| r.id).collect();
        got.sort_unstable();
        ids.sort_unstable();
        assert_eq!(got, ids, "seed {seed}: every submitted id answered exactly once");

        let s = coord.secure_summary();
        assert!(
            s.sessions_retried >= 1 || s.party_reconnects >= 1,
            "seed {seed}: no recovery observed (retried={} reconnects={})",
            s.sessions_retried,
            s.party_reconnects
        );

        // Workers are still alive: a post-fault request completes cleanly.
        let r = coord.infer_blocking(token_input(&cfg, seed + 99), EngineKind::Secure);
        assert!(r.error.is_none(), "seed {seed}: post-fault request failed: {:?}", r.error);
        assert_eq!(r.logits.len(), cfg.num_labels);
        let s = coord.secure_summary();
        assert!(s.link_up, "seed {seed}: link did not settle back up");
        coord.shutdown();
    }
}

/// Kill the link, then sabotage the *re-dial* mid-handshake: the
/// supervisor's backoff loop must absorb the half-dead dial and land
/// the one after it.
#[test]
fn mid_handshake_cut_recovers() {
    for seed in [1u64, 2, 3] {
        let cfg = tiny();
        let w = random_weights(&cfg, 13);
        let host_addr = spawn_host(&cfg, &w);
        let proxy = ChaosProxy::start(&host_addr.to_string()).expect("proxy");
        let coord = chaos_coordinator(&cfg, &w, &proxy);

        let r = coord.infer_blocking(token_input(&cfg, seed), EngineKind::Secure);
        assert!(r.error.is_none(), "seed {seed}: baseline request failed");

        // The next accepted connection (the re-dial) dies a few bytes
        // into the HELLO exchange (the fingerprint alone is 32 bytes).
        proxy.cut_next_after(8 + seed);
        proxy.sever_all();

        let r = coord.infer_blocking(token_input(&cfg, seed + 1), EngineKind::Secure);
        assert!(r.error.is_none(), "seed {seed}: request after handshake cut failed: {:?}", r.error);
        assert_eq!(r.logits.len(), cfg.num_labels);

        let s = coord.secure_summary();
        assert!(s.party_reconnects >= 1, "seed {seed}: reconnect counter never ticked");
        assert!(s.link_up, "seed {seed}: link down after recovery");
        coord.shutdown();
    }
}

/// The party host "restarts" between batches: a fresh host comes up on
/// a new address, the proxy is repointed, the old connections die.
/// Subsequent requests must ride the re-dial onto the new host.
#[test]
fn party_restart_between_batches() {
    for seed in [1u64, 2, 3] {
        let cfg = tiny();
        let w = random_weights(&cfg, 13);
        let first = spawn_host(&cfg, &w);
        let proxy = ChaosProxy::start(&first.to_string()).expect("proxy");
        let coord = chaos_coordinator(&cfg, &w, &proxy);

        let r = coord.infer_blocking(token_input(&cfg, seed), EngineKind::Secure);
        assert!(r.error.is_none(), "seed {seed}: pre-restart request failed");

        // Same weights + config ⇒ same fingerprint: the replacement
        // host accepts the supervisor's re-handshake.
        let second = spawn_host(&cfg, &w);
        proxy.set_upstream(&second.to_string());
        proxy.sever_all();

        for i in 0..3u64 {
            let r = coord.infer_blocking(token_input(&cfg, seed + 10 + i), EngineKind::Secure);
            assert!(
                r.error.is_none(),
                "seed {seed}: post-restart request {i} failed: {:?}",
                r.error
            );
            assert_eq!(r.logits.len(), cfg.num_labels);
        }
        let s = coord.secure_summary();
        assert!(s.party_reconnects >= 1, "seed {seed}: restart produced no reconnect");
        assert!(s.link_up, "seed {seed}: link down after restart recovery");
        coord.shutdown();
    }
}

/// 100 connections that dial the host and vanish — some silently, some
/// after a burst of garbage — must all be reaped: no leaked connection
/// or session threads, and the host still serves a real session after.
#[test]
fn host_cleans_up_churned_connections() {
    let cfg = tiny();
    let w = random_weights(&cfg, 13);
    let (addr, stats) = spawn_party_host_stats(
        cfg.clone(),
        Arc::new(shares1(&w)),
        None,
        PartyHostConfig::default(),
    )
    .expect("party host");

    for i in 0..100 {
        let mut s = TcpStream::connect(addr).expect("churn dial");
        if i % 3 == 0 {
            // Not a HELLO frame: the handshake must reject and close.
            let _ = s.write_all(&[0xde, 0xad, 0xbe, 0xef]);
        }
        drop(s);
    }

    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let conns = stats.active_conns.load(Ordering::Relaxed);
        let sessions = stats.active();
        if conns == 0 && sessions == 0 {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "leak after churn: {conns} connections, {sessions} sessions still active"
        );
        std::thread::sleep(Duration::from_millis(20));
    }

    // The accept loop survived the abuse: a real handshake + session
    // still completes.
    let rp = RemoteParty::connect(&addr.to_string(), &cfg, &shares1(&w), None)
        .expect("post-churn handshake");
    let mut model = SecureModel::new(cfg.clone(), &w, OfflineMode::Seeded);
    model.set_peer_runtime(PeerRuntime::Remote(rp));
    let out = model.infer(&token_input(&cfg, 7));
    assert_eq!(out.logits.len(), cfg.num_labels);
    assert!(out.logits.iter().all(|v| v.is_finite()));
}

/// Admission control on the party host: a `--max-sessions 1` host under
/// four concurrent coordinator workers must answer every excess START
/// with a `SHED` frame that surfaces as a typed
/// [`SessionError::Overloaded`] reply — never a hang, never a silently
/// dropped request, never a spent retry — while admitted sessions keep
/// completing and the shed counter reconciles exactly.
#[test]
fn party_host_sheds_excess_sessions_with_typed_overload() {
    let cfg = tiny();
    let w = random_weights(&cfg, 13);
    let (addr, stats) = spawn_party_host_stats(
        cfg.clone(),
        Arc::new(shares1(&w)),
        None,
        PartyHostConfig { max_sessions: 1, ..PartyHostConfig::default() },
    )
    .expect("party host");

    let coord = Coordinator::start_with(
        cfg.clone(),
        w.clone(),
        None,
        // One request per session so four workers race four concurrent
        // STARTs at the cap-1 host.
        BatcherConfig { max_batch: 1, ..BatcherConfig::default() },
        ServingConfig {
            secure_workers: 4,
            batch_buckets: vec![1],
            peer_addr: Some(addr.to_string()),
            ..ServingConfig::default()
        },
    )
    .expect("coordinator");

    let (tx, rx) = std::sync::mpsc::channel();
    let total = 12usize;
    for i in 0..total {
        coord.submit(token_input(&cfg, i as u64), EngineKind::Secure, tx.clone());
    }
    drop(tx);

    let (mut ok, mut shed) = (0u64, 0u64);
    for _ in 0..total {
        let r = rx
            .recv_timeout(Duration::from_secs(60))
            .expect("request lost — a shed must reply, not hang");
        assert_clean_reply(&r, cfg.num_labels, "host admission");
        match &r.error {
            None => ok += 1,
            Some(SessionError::Overloaded) => shed += 1,
            Some(e) => panic!("expected Overloaded for refused sessions, got: {e}"),
        }
    }
    assert!(ok >= 1, "the admitted session must complete");
    assert!(shed >= 1, "cap-1 host under 4 concurrent workers never shed");
    assert_eq!(
        stats.sessions_shed.load(Ordering::Relaxed),
        shed,
        "host shed counter must reconcile with the typed replies"
    );
    // A shed is terminal admission feedback, not a link fault: the
    // retry budget stays untouched.
    let s = coord.secure_summary();
    assert_eq!(s.sessions_retried, 0, "a shed must not spend the retry budget");

    // The workers survived the refusals: a quiet follow-up completes.
    // (The host decrements its session gauge just after the RESULT
    // ships, so an immediate follow-up may still catch the cap — a
    // shed there is admission control working, not a failure.)
    let mut ok_after = false;
    for _ in 0..50 {
        let r = coord.infer_blocking(token_input(&cfg, 99), EngineKind::Secure);
        match &r.error {
            None => {
                ok_after = true;
                break;
            }
            Some(SessionError::Overloaded) => std::thread::sleep(Duration::from_millis(10)),
            Some(e) => panic!("post-shed request failed with a non-shed error: {e}"),
        }
    }
    assert!(ok_after, "host never admitted a session after the burst drained");
    coord.shutdown();
}

/// Pull one gauge value out of a Prometheus exposition body.
fn metric_value(body: &str, needle: &str) -> f64 {
    body.lines()
        .find(|l| l.starts_with(needle))
        .and_then(|l| l.rsplit(' ').next())
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| panic!("metric {needle} missing from:\n{body}"))
}

/// Scheduler hygiene under churn: after a concurrent burst through the
/// full remote stack (coordinator carriers parking across real TCP
/// waits, party sessions contending for compute permits), every
/// scheduler gauge on BOTH processes — running, parked, waiting — must
/// settle back to zero, and shutdown must drain cleanly rather than
/// strand a carrier.
#[test]
fn scheduler_gauges_drain_to_zero_after_churn() {
    let cfg = tiny();
    let w = random_weights(&cfg, 13);
    let (addr, stats) = spawn_party_host_stats(
        cfg.clone(),
        Arc::new(shares1(&w)),
        None,
        PartyHostConfig { compute_permits: 2, ..PartyHostConfig::default() },
    )
    .expect("party host");

    let coord = Coordinator::start_with(
        cfg.clone(),
        w.clone(),
        None,
        BatcherConfig { max_batch: 1, ..BatcherConfig::default() },
        ServingConfig {
            secure_workers: 2,
            // More carriers than permits: sessions must park across the
            // party link's wire waits for the burst to drain.
            max_sessions: 6,
            batch_buckets: vec![1],
            peer_addr: Some(addr.to_string()),
            ..ServingConfig::default()
        },
    )
    .expect("coordinator");

    std::thread::scope(|scope| {
        for c in 0..6u64 {
            let coord = &coord;
            let cfg = &cfg;
            scope.spawn(move || {
                for i in 0..3u64 {
                    let r = coord.infer_blocking(token_input(cfg, c * 10 + i), EngineKind::Secure);
                    assert!(r.error.is_none(), "churn request failed: {:?}", r.error);
                    assert_eq!(r.logits.len(), cfg.num_labels);
                }
            });
        }
    });

    // Coordinator gauges drain.
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let g = coord.sched_snapshot();
        if g.running == 0 && g.parked == 0 && g.waiting == 0 {
            break;
        }
        assert!(Instant::now() < deadline, "coordinator scheduler never drained: {g:?}");
        std::thread::sleep(Duration::from_millis(20));
    }
    // Host session gauge drains (permits release before session exit).
    loop {
        if stats.active() == 0 {
            break;
        }
        assert!(Instant::now() < deadline, "party sessions never drained");
        std::thread::sleep(Duration::from_millis(20));
    }
    // And the host's exported scheduler gauges agree.
    let body = fetch_party_metrics(&addr.to_string(), None).expect("party metrics");
    for state in ["running", "parked", "waiting"] {
        let v = metric_value(
            &body,
            &format!("secformer_sched_sessions{{role=\"party\",state=\"{state}\"}}"),
        );
        assert_eq!(v, 0.0, "host sched gauge {state} stuck non-zero");
    }
    assert_eq!(metric_value(&body, "secformer_sessions_shed_total{role=\"party\"}"), 0.0);

    // Clean drain on shutdown: this must return, not hang on a carrier.
    coord.shutdown();
}

/// [`BundleSource`] wrapper that records every bundle handed to the
/// engine, so the test can pin *which* pad material each session
/// attempt consumed.
struct RecordingSource {
    inner: Arc<PoolSet>,
    popped: Mutex<Vec<(u64, String)>>,
}

impl RecordingSource {
    fn record(&self, b: Option<SessionBundle>) -> Option<SessionBundle> {
        if let Some(b) = &b {
            self.popped.lock().unwrap().push((b.seq, b.session.clone()));
        }
        b
    }
}

impl BundleSource for RecordingSource {
    fn pop(&self, kind: PlanInput) -> Option<SessionBundle> {
        self.record(BundleSource::pop(&*self.inner, kind))
    }
    fn pop_batch(&self, kind: PlanInput, batch: usize) -> Option<SessionBundle> {
        self.record(BundleSource::pop_batch(&*self.inner, kind, batch))
    }
    fn try_pop(&self, kind: PlanInput) -> Option<SessionBundle> {
        BundleSource::try_pop(&*self.inner, kind)
    }
    fn note_fallback(&self) {
        BundleSource::note_fallback(&*self.inner)
    }
    fn snapshot(&self) -> PoolSnapshot {
        BundleSource::snapshot(&*self.inner)
    }
    fn stop(&self) {
        BundleSource::stop(&*self.inner)
    }
}

/// The retry-safety invariant, pinned at the engine level: a session
/// that dies and is retried consumes a NEW session label and a NEW pad
/// bundle — nothing masked with the dead session's one-time-pad
/// material is ever re-sent. Bundle `seq` mirrors the engine's session
/// counter and bundle `session` is `{prefix}-{seq}`, so recording the
/// pops pins both the label freshness and the pad freshness at once.
#[test]
fn retry_mints_fresh_label_and_consumes_fresh_bundle() {
    let cfg = tiny();
    let w = random_weights(&cfg, 13);
    let host_addr = spawn_host(&cfg, &w);
    let proxy = ChaosProxy::start(&host_addr.to_string()).expect("proxy");

    let rec = Arc::new(RecordingSource {
        inner: PoolSet::start(
            &cfg,
            "fresh",
            PoolConfig { target_depth: 4, producers: 1, ..PoolConfig::default() },
            false,
        ),
        popped: Mutex::new(Vec::new()),
    });
    let mut model = SecureModel::new_pooled(cfg.clone(), &w, rec.clone());
    model.set_session_label("fresh");

    let sup = PartyLinkSupervisor::connect(
        &proxy.addr().to_string(),
        &cfg,
        Arc::new(shares1(&w)),
        None,
        fast_link(),
        RedialPolicy::default(),
    )
    .expect("supervised link");
    model.set_peer_runtime(PeerRuntime::Supervised(sup.clone()));

    let input = token_input(&cfg, 5);
    let healthy = model.try_infer(&input).expect("healthy session");
    assert_eq!(healthy.logits.len(), cfg.num_labels);

    // Provoke a failed attempt. If the heartbeat reader wins the race
    // and the supervisor re-dials before our write (transparent
    // recovery, no session error), sever again — bounded attempts.
    let mut provoked = false;
    for _ in 0..10 {
        proxy.sever_all();
        match model.try_infer(&input) {
            Err(e) => {
                assert!(e.is_retryable(), "expected a retryable link error, got: {e}");
                provoked = true;
                break;
            }
            Ok(_) => continue,
        }
    }
    assert!(provoked, "could not provoke a session failure through the proxy");

    // The retry: the supervisor re-dials and the session must succeed.
    let retried = model.try_infer(&input).expect("retried session");
    assert_eq!(retried.logits.len(), cfg.num_labels);
    assert!(retried.logits.iter().all(|v| v.is_finite()));
    assert!(sup.reconnects() >= 1, "retry succeeded without a re-dial");

    // Every attempt — healthy, severed, failed and retried alike —
    // consumed its own bundle: strictly increasing seq (the session
    // counter) and a never-repeated session label.
    let popped = rec.popped.lock().unwrap().clone();
    assert!(popped.len() >= 3, "expected ≥3 pops (healthy, failed, retried): {popped:?}");
    for (i, (seq, session)) in popped.iter().enumerate() {
        let expect = (i + 1) as u64;
        assert_eq!(*seq, expect, "bundle seq must advance every attempt: {popped:?}");
        assert_eq!(
            session,
            &format!("fresh-{expect}"),
            "bundle label must match the freshly minted session label: {popped:?}"
        );
    }
    sup.stop();
}
