//! Integration tests for cross-request batched secure inference (the
//! PR 5 tentpole): one round schedule for the whole dynamic batch.
//!
//! 1. rounds invariant — total online rounds of a batch of B equal a
//!    SINGLE inference's rounds, for any B and head count;
//! 2. correctness — each batched item's logits match the plaintext
//!    reference and a solo run (within 2× the per-run fixed-point
//!    bound), padding included;
//! 3. mixed token/hidden batches split into per-kind chunks correctly;
//! 4. pooled batches consume ONE plan-exact batch bundle: zero online
//!    dealer messages, hit rate 1.0;
//! 5. remote (`party-serve`) batched sessions are bit-identical to the
//!    in-process engine;
//! 6. the coordinator amortizes rounds across its dynamic batch (the
//!    `rounds_per_request` gauge drops), and the simulated-LAN bill of
//!    a batch of 8 beats 8 sequential schedules by ≥ 2×.

use secformer::coordinator::{BatcherConfig, Coordinator, EngineKind, ServingConfig};
use secformer::core::rng::Xoshiro;
use secformer::engine::{OfflineMode, SecureModel};
use secformer::net::stats::NetModel;
use secformer::nn::config::{Framework, ModelConfig};
use secformer::nn::model::{ref_forward, ModelInput};
use secformer::nn::weights::{random_weights, share_weights};
use secformer::offline::pool::PoolConfig;
use secformer::offline::source::{BundleSource, PoolSet};
use secformer::party::runtime::{spawn_party_host, PartyHostConfig};
use std::sync::Arc;
use std::time::Duration;

fn tiny() -> ModelConfig {
    ModelConfig::tiny(8, Framework::SecFormer)
}

fn hidden_input(cfg: &ModelConfig, seed: u64) -> ModelInput {
    let mut rng = Xoshiro::seed_from(seed);
    ModelInput::Hidden((0..cfg.seq * cfg.hidden).map(|_| rng.normal() * 0.5).collect())
}

fn token_input(cfg: &ModelConfig, salt: u32) -> ModelInput {
    ModelInput::Tokens(
        (0..cfg.seq as u32).map(|i| (i + salt) % cfg.vocab as u32).collect(),
    )
}

#[test]
fn batch_rounds_equal_single_inference_rounds() {
    let cfg = tiny();
    let w = random_weights(&cfg, 0xBA01);
    let single = SecureModel::new(cfg.clone(), &w, OfflineMode::Seeded)
        .infer(&hidden_input(&cfg, 1));
    for b in [2usize, 4, 8] {
        let mut m = SecureModel::new(cfg.clone(), &w, OfflineMode::Seeded);
        m.set_batch_buckets(&[b]);
        let inputs: Vec<ModelInput> =
            (0..b).map(|i| hidden_input(&cfg, 10 + i as u64)).collect();
        let r = m.infer_batch(&inputs);
        assert_eq!(r.chunks, 1, "B={b}: a homogeneous batch shares one schedule");
        assert_eq!(
            r.stats.total_rounds(),
            single.stats.total_rounds(),
            "B={b}: batch rounds must equal a single inference's rounds"
        );
        assert!(
            r.stats.total_bytes() > single.stats.total_bytes(),
            "B={b}: volume must scale with the batch"
        );
    }
    // Head-count independence (the PR 1 invariant) carries over to the
    // batched schedule: fewer heads, same rounds.
    let mut c2 = cfg.clone();
    c2.heads = 2;
    let w2 = random_weights(&c2, 0xBA02);
    let mut m = SecureModel::new(c2.clone(), &w2, OfflineMode::Seeded);
    m.set_batch_buckets(&[4]);
    let inputs: Vec<ModelInput> = (0..4).map(|i| hidden_input(&c2, 30 + i)).collect();
    let r = m.infer_batch(&inputs);
    assert_eq!(r.stats.total_rounds(), single.stats.total_rounds());
}

#[test]
fn batched_items_match_reference_and_solo_runs() {
    let cfg = tiny();
    let w = random_weights(&cfg, 0xBA03);
    let inputs: Vec<ModelInput> = (0..4).map(|i| hidden_input(&cfg, 40 + i)).collect();
    let mut m = SecureModel::new(cfg.clone(), &w, OfflineMode::Seeded);
    m.set_batch_buckets(&[4]);
    let r = m.infer_batch(&inputs);
    assert_eq!(r.logits.len(), 4);
    for (i, input) in inputs.iter().enumerate() {
        let expect = ref_forward(&cfg, &w, input);
        let solo = SecureModel::new(cfg.clone(), &w, OfflineMode::Seeded).infer(input);
        for j in 0..cfg.num_labels {
            assert!(
                (r.logits[i][j] - expect[j]).abs() < 0.2,
                "item {i} logit {j}: batch={} ref={}",
                r.logits[i][j],
                expect[j]
            );
            // Batch and solo runs draw independent correlated
            // randomness, so compare within 2× the per-run bound.
            assert!(
                (r.logits[i][j] - solo.logits[j]).abs() < 0.4,
                "item {i} logit {j}: batch={} solo={}",
                r.logits[i][j],
                solo.logits[j]
            );
        }
    }
}

#[test]
fn partial_batch_pads_to_bucket_and_drops_padding() {
    let cfg = tiny();
    let w = random_weights(&cfg, 0xBA04);
    let inputs: Vec<ModelInput> = (0..3).map(|i| hidden_input(&cfg, 50 + i)).collect();
    let mut m = SecureModel::new(cfg.clone(), &w, OfflineMode::Seeded);
    m.set_batch_buckets(&[4]); // 3 requests pad up to the 4-bucket
    let r = m.infer_batch(&inputs);
    assert_eq!(r.chunks, 1, "padding keeps one schedule");
    assert_eq!(r.logits.len(), 3, "padding outputs are dropped");
    for (i, input) in inputs.iter().enumerate() {
        let expect = ref_forward(&cfg, &w, input);
        for j in 0..cfg.num_labels {
            assert!(
                (r.logits[i][j] - expect[j]).abs() < 0.2,
                "item {i} logit {j}: got={} ref={}",
                r.logits[i][j],
                expect[j]
            );
        }
    }
}

#[test]
fn mixed_kind_batches_split_into_per_kind_chunks() {
    let cfg = tiny();
    let w = random_weights(&cfg, 0xBA05);
    let inputs = vec![
        token_input(&cfg, 1),
        hidden_input(&cfg, 61),
        token_input(&cfg, 2),
        hidden_input(&cfg, 62),
    ];
    let mut m = SecureModel::new(cfg.clone(), &w, OfflineMode::Seeded);
    m.set_batch_buckets(&[1, 2, 4, 8]);
    let r = m.infer_batch(&inputs);
    assert_eq!(r.chunks, 2, "one chunk per input kind");
    assert_eq!(r.logits.len(), 4);
    for (i, input) in inputs.iter().enumerate() {
        let expect = ref_forward(&cfg, &w, input);
        for j in 0..cfg.num_labels {
            assert!(
                (r.logits[i][j] - expect[j]).abs() < 0.25,
                "item {i} logit {j}: got={} ref={}",
                r.logits[i][j],
                expect[j]
            );
        }
    }
}

#[test]
fn unfused_batches_run_sequentially_but_stay_correct() {
    let mut cfg = tiny();
    cfg.fused_attention = false;
    let w = random_weights(&cfg, 0xBA06);
    let single = SecureModel::new(cfg.clone(), &w, OfflineMode::Seeded)
        .infer(&hidden_input(&cfg, 70));
    let inputs: Vec<ModelInput> = (0..2).map(|i| hidden_input(&cfg, 71 + i)).collect();
    let mut m = SecureModel::new(cfg.clone(), &w, OfflineMode::Seeded);
    m.set_batch_buckets(&[2]);
    let r = m.infer_batch(&inputs);
    // The pre-fusion baseline has no batched form: B independent
    // schedules, so rounds scale with B.
    assert_eq!(r.stats.total_rounds(), 2 * single.stats.total_rounds());
    for (i, input) in inputs.iter().enumerate() {
        let expect = ref_forward(&cfg, &w, input);
        for j in 0..cfg.num_labels {
            assert!((r.logits[i][j] - expect[j]).abs() < 0.2, "item {i} logit {j}");
        }
    }
}

#[test]
fn pooled_batches_keep_zero_dealer_msgs_and_full_hit_rate() {
    let cfg = tiny();
    let w = random_weights(&cfg, 0xBA07);
    let pools = PoolSet::start_with_buckets(
        &cfg,
        "batch-pool",
        PoolConfig { target_depth: 2, producers: 1, ..PoolConfig::default() },
        true,
        &[4],
    );
    pools.warm(1);
    let mut m = SecureModel::new_pooled(cfg.clone(), &w, pools.clone());
    m.set_batch_buckets(&[4]);
    // One hidden batch and one token batch: both (kind, 4) pools serve.
    let makers: [fn(u64) -> ModelInput; 2] = [
        |i| hidden_input(&tiny(), 80 + i),
        |i| token_input(&tiny(), 80 + i as u32),
    ];
    for mk in makers {
        let inputs: Vec<ModelInput> = (0..4).map(mk).collect();
        let r = m.infer_batch(&inputs);
        assert_eq!(r.chunks, 1);
        assert_eq!(
            r.stats.offline_msgs, 0,
            "pooled batch must never consult a dealer online"
        );
        assert!(r.stats.offline_bytes > 0, "the batch bundle is accounted");
        for logits in &r.logits {
            assert!(logits.iter().all(|v| v.is_finite()));
        }
    }
    let snap = pools.snapshot();
    assert_eq!(snap.consumed, 2, "ONE bundle per 4-request batch");
    assert_eq!(
        snap.misses, 0,
        "batch manifests must be plan-exact (no in-session fallback): {snap:?}"
    );
    assert!((snap.hit_rate() - 1.0).abs() < 1e-9);
    pools.stop();
}

#[test]
fn remote_party_batch_is_bit_identical_to_in_process() {
    let cfg = tiny();
    let w = random_weights(&cfg, 0xBA08);
    let (_s0, s1) = share_weights(&w, &mut Xoshiro::seed_from(0x5EC0));
    let addr = spawn_party_host(
        cfg.clone(),
        Arc::new(s1),
        None,
        PartyHostConfig::default(),
    )
    .expect("spawn party host");

    // Mixed batch: the hidden chunk ships as ONE START_BATCH frame, the
    // lone token item as a classic START — both paths must match the
    // in-process engine bit for bit (same labels, same seeded streams).
    let inputs = vec![
        hidden_input(&cfg, 90),
        token_input(&cfg, 9),
        hidden_input(&cfg, 91),
        hidden_input(&cfg, 92),
    ];
    let mut local = SecureModel::new(cfg.clone(), &w, OfflineMode::Seeded);
    local.set_session_label("batch-2p");
    local.set_batch_buckets(&[1, 2, 4, 8]);
    let a = local.infer_batch(&inputs);

    let mut remote = SecureModel::new(cfg.clone(), &w, OfflineMode::Seeded);
    remote.set_session_label("batch-2p");
    remote.set_batch_buckets(&[1, 2, 4, 8]);
    remote
        .connect_remote_peer(&addr.to_string(), None)
        .expect("connect to party host");
    let b = remote.infer_batch(&inputs);

    assert_eq!(a.logits, b.logits, "remote batch must be bit-identical");
    assert_eq!(a.chunks, b.chunks);
    assert_eq!(a.stats.total_rounds(), b.stats.total_rounds());
    assert_eq!(a.stats.total_bytes(), b.stats.total_bytes());
}

#[test]
fn coordinator_amortizes_rounds_across_the_dynamic_batch() {
    let cfg = tiny();
    let w = random_weights(&cfg, 0xBA09);
    let single_rounds = SecureModel::new(cfg.clone(), &w, OfflineMode::Seeded)
        .infer(&token_input(&cfg, 0))
        .stats
        .total_rounds();

    // A straggler window far beyond any CI scheduling hiccup, so all 8
    // submissions deterministically join ONE drain. This does not slow
    // the test down: drain_batch returns the moment the queue reaches
    // max_batch, and the quick submit loop below fills it in well under
    // the window.
    let c = Coordinator::start_with(
        cfg.clone(),
        w,
        None,
        BatcherConfig { max_batch: 8, max_wait: Duration::from_secs(30) },
        ServingConfig::default(), // seeded, batch_buckets 1,2,4,8
    )
    .unwrap();
    let (tx, rx) = std::sync::mpsc::channel();
    for i in 0..8 {
        c.submit(token_input(&cfg, i), EngineKind::Secure, tx.clone());
    }
    for _ in 0..8 {
        let r = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        assert_eq!(r.logits.len(), cfg.num_labels);
        assert!(r.logits.iter().all(|v| v.is_finite()));
    }
    let s = c.secure_summary();
    assert_eq!(s.count, 8);
    assert!(
        s.mean_batch_size >= 2.0,
        "the burst must coalesce into dynamic batches: mean {}",
        s.mean_batch_size
    );
    assert!(
        s.rounds_per_request <= single_rounds as f64 / 2.0,
        "rounds/request must amortize: {} vs single {}",
        s.rounds_per_request,
        single_rounds
    );
    assert!(!s.batch_hist.is_empty());
    c.shutdown();
}

#[test]
fn batched_lan_bill_beats_sequential_by_2x_at_b8() {
    // Deterministic network-bill comparison (counted rounds/bytes through
    // the paper's LAN model, as in tests/round_fusion.rs): 8 sequential
    // schedules vs one batched schedule for the same 8 inferences.
    let cfg = tiny();
    let w = random_weights(&cfg, 0xBA0A);
    let single = SecureModel::new(cfg.clone(), &w, OfflineMode::Seeded)
        .infer(&hidden_input(&cfg, 100));
    let inputs: Vec<ModelInput> = (0..8).map(|i| hidden_input(&cfg, 101 + i)).collect();
    let mut m = SecureModel::new(cfg.clone(), &w, OfflineMode::Seeded);
    m.set_batch_buckets(&[8]);
    let batched = m.infer_batch(&inputs);

    let lan = NetModel::paper_lan();
    let seq_bill = lan.simulated_seconds(
        8 * single.stats.total_rounds(),
        8 * single.stats.total_bytes() * 2,
    );
    let batch_bill = lan.simulated_seconds(
        batched.stats.total_rounds(),
        batched.stats.total_bytes() * 2,
    );
    assert!(
        seq_bill >= 2.0 * batch_bill,
        "simulated-LAN bill must improve ≥2× at B=8: sequential {seq_bill:.6}s vs \
         batched {batch_bill:.6}s"
    );
}
